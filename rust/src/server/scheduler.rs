//! Request scheduler: SLO-tier-aware queue feeding the continuous
//! batcher.
//!
//! Two consumption modes:
//!  * [`Scheduler::next_batch`] — blocking greedy batch formation
//!    (waits up to `batch_window` for the batch to fill once one
//!    request is pending). The batcher uses it only when idle, so an
//!    initial burst is admitted together.
//!  * [`Scheduler::take`] — non-blocking drain of up to N requests,
//!    polled every decode step to admit work into free slots
//!    *mid-flight* while other slots keep decoding.
//!
//! Slots in the same decode call carry per-slot masks (the [B, L, m]
//! mask tensor), so heterogeneous strategies batch together.
//!
//! **Tier-aware drain order**: both drains hand requests out ordered by
//! (age-promoted [`Tier`](super::protocol::Tier) rank, arrival index)
//! — `interactive` ahead of
//! `standard` ahead of `batch`, strict FCFS *within* a tier. To keep a
//! sustained interactive burst from starving lower tiers, a queued
//! request is promoted one rank toward the front for every
//! [`STARVATION_PROMOTE_MS`] it has waited, so batch work ages into the
//! interactive rank and then drains FCFS. Reported queue positions
//! ([`Scheduler::submit`]'s return, [`Scheduler::queued_sessions`]) are
//! clamped per session to be **monotone non-increasing**: a later
//! higher-tier arrival may push a session back in *actual* drain order,
//! but the position it reports never grows.
//!
//! **Prefix grouping** (optional): when `prefix_group_bytes > 0`, each
//! drained batch is stable-reordered so requests sharing at least that
//! many leading prompt bytes sit adjacent, in first-arrival order. The
//! batcher admits a batch front-to-back and defers same-prefix
//! followers while the first request's prefill is still streaming, so
//! a shared-prefix burst pays its cache miss **once** — the followers
//! splice the published prefix instead of recomputing it.
//!
//! **Control plane** (protocol v2): the scheduler also carries
//! [`Control`] messages — client-initiated `cancel` and mid-stream
//! `set` knob adjustments — from the reactor to the shard's batcher
//! loop, which drains them with [`Scheduler::take_controls`] at the top
//! of every iteration. A pending control wakes an idle batcher blocked
//! in [`Scheduler::next_batch`] (which then returns an empty batch), so
//! a cancel is never stuck behind "no new work". [`Scheduler::remove`]
//! plucks a still-queued request out of the queue (cancel before
//! admission); [`Scheduler::drain_close`] closes the queue and returns
//! everything still queued — graceful shutdown fails those with a
//! retryable error instead of serving them.

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

use super::protocol::Request;

/// Milliseconds of queue wait per one-rank promotion toward the front
/// of the drain order — the anti-starvation clock: a `batch` request
/// that has waited 2× this is ranked like an `interactive` one (and
/// then drains FCFS among them), so no tier starves behind a sustained
/// higher-tier burst.
pub const STARVATION_PROMOTE_MS: u64 = 250;

/// One control-plane message for a shard's batcher loop, keyed by the
/// (connection, session id) pair that uniquely names a live session.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Control {
    /// Stop the session now: free its slot (or pluck it from the
    /// queue), emit a terminal `done` with finish "cancel", re-queue
    /// nothing.
    Cancel { conn_id: u64, id: u64 },
    /// Adjust the session's mask-refresh interval mid-stream.
    SetRefresh {
        conn_id: u64,
        id: u64,
        refresh_every: usize,
    },
    /// Backpressure: the session's client stopped draining its socket
    /// (write buffer crossed the high-water mark). Pause the session's
    /// decode slot — keep the slot, KV state, and emitter intact, emit
    /// nothing, burn no engine steps on it — instead of disconnecting
    /// the slow consumer. A session not yet admitted is remembered and
    /// placed paused.
    Park { conn_id: u64, id: u64 },
    /// Backpressure released: the client's write buffer drained below
    /// the low-water mark; resume the paused slot exactly where it
    /// stopped (byte-identical continuation).
    Unpark { conn_id: u64, id: u64 },
}

impl Control {
    /// The (conn, session) key this control targets.
    pub fn key(&self) -> (u64, u64) {
        match *self {
            Control::Cancel { conn_id, id }
            | Control::SetRefresh { conn_id, id, .. }
            | Control::Park { conn_id, id }
            | Control::Unpark { conn_id, id } => (conn_id, id),
        }
    }
}

/// Queue entry: the request plus its arrival time and a reply slot key.
#[derive(Debug)]
pub struct Pending {
    /// The parsed request to serve.
    pub request: Request,
    /// Submission time — the basis of the `queue_ms` stat.
    pub arrived: Instant,
    /// Opaque connection key used by the server to route the response.
    pub conn_id: u64,
    /// Emit non-terminal events (delta/refresh) for this session —
    /// protocol-v2 streams. v1 one-shot requests set false so the
    /// batcher skips the per-token event cost their compatibility shim
    /// would discard anyway; terminals are always emitted.
    pub stream: bool,
    /// Delta frames the client already received (protocol-v2 `resume`).
    /// The batcher re-runs the deterministic decode but suppresses
    /// deltas with index < `resume_from`, so the reconnecting client's
    /// stream continues exactly where it broke off. 0 = fresh session.
    pub resume_from: u64,
    /// The governor already rewrote this request's knobs (degraded
    /// admission). Sticky across requeues so a re-admission never
    /// compounds the degradation. Initialize to `false`.
    pub degraded: bool,
    /// Lowest queue position ever reported for this session (submit
    /// `accepted` frame or a `queue` update). Maintained by the
    /// scheduler so reported positions are monotone non-increasing
    /// even when tier ordering moves the session back. Initialize to
    /// `usize::MAX`.
    pub reported_floor: usize,
}

/// Drain rank of one queued request right now: its tier rank, promoted
/// one step toward the front per [`STARVATION_PROMOTE_MS`] waited.
fn effective_rank(p: &Pending, now: Instant) -> u8 {
    let waited_ms =
        now.saturating_duration_since(p.arrived).as_millis() as u64;
    let promoted = (waited_ms / STARVATION_PROMOTE_MS).min(u64::from(u8::MAX));
    p.request.tier.rank().saturating_sub(promoted as u8)
}

/// Queue indices in drain order: ascending (effective rank, arrival
/// index) — FCFS within a rank, `interactive` first.
fn drain_order(queue: &VecDeque<Pending>, now: Instant) -> Vec<usize> {
    let mut order: Vec<usize> = (0..queue.len()).collect();
    order.sort_by_key(|&i| (effective_rank(&queue[i], now), i));
    order
}

/// Remove the first `n` entries of the drain order from the queue,
/// returning them in drain order; the remainder keeps arrival order
/// (so FCFS-within-tier is preserved for the next drain).
fn drain_ordered(st: &mut QueueState, n: usize) -> Vec<Pending> {
    let n = st.queue.len().min(n);
    if n == 0 {
        return Vec::new();
    }
    let order = drain_order(&st.queue, Instant::now());
    let mut items: Vec<Option<Pending>> =
        st.queue.drain(..).map(Some).collect();
    let batch: Vec<Pending> = order
        .iter()
        .take(n)
        .filter_map(|&i| items[i].take())
        .collect();
    st.queue.extend(items.into_iter().flatten());
    batch
}

#[derive(Default)]
struct QueueState {
    queue: VecDeque<Pending>,
    controls: Vec<Control>,
    closed: bool,
}

/// Thread-safe scheduler queue.
pub struct Scheduler {
    state: Mutex<QueueState>,
    cv: Condvar,
    /// Most requests a single [`Scheduler::next_batch`] drain returns.
    pub batch_width: usize,
    /// How long a non-empty partial batch waits to fill before it is
    /// handed out anyway — the classic latency/throughput knob.
    pub batch_window: Duration,
    /// Cluster drained batches by shared prompt prefix of at least this
    /// many bytes (0 = off, strict FCFS output order).
    pub prefix_group_bytes: usize,
}

impl Scheduler {
    /// A scheduler draining up to `batch_width` requests per batch,
    /// waiting up to `batch_window` for a partial batch to fill.
    pub fn new(batch_width: usize, batch_window: Duration) -> Scheduler {
        Scheduler {
            state: Mutex::new(QueueState::default()),
            cv: Condvar::new(),
            batch_width,
            batch_window,
            prefix_group_bytes: 0,
        }
    }

    /// Builder-style knob: enable same-prefix clustering of drained
    /// batches (`min_shared` leading prompt bytes; 0 disables).
    pub fn with_prefix_grouping(mut self, min_shared: usize) -> Scheduler {
        self.prefix_group_bytes = min_shared;
        self
    }

    /// Lock the queue state, recovering from poisoning: a batcher
    /// thread that panicked mid-step must not wedge the reactors that
    /// submit to this queue (or vice versa). The queue is a plain
    /// FCFS list whose invariant holds at every panic point, so
    /// degrade loudly and keep scheduling.
    fn locked(&self) -> std::sync::MutexGuard<'_, QueueState> {
        self.state.lock().unwrap_or_else(|poisoned| {
            crate::warn_!("scheduler mutex poisoned; recovering");
            poisoned.into_inner()
        })
    }

    /// Enqueue a request, returning its position in the tier-aware
    /// drain order at submission (0 = next to be drained) — the v2
    /// `accepted` frame's `queue_pos`. An `interactive` request lands
    /// ahead of queued `batch` work, so its reported position reflects
    /// what it will actually wait behind. Returns `None` (refusing the
    /// request) once the queue is closed: after shutdown's drain,
    /// nothing will ever dequeue again, so enqueueing would strand the
    /// session without a terminal — the caller must fail it itself
    /// (retryably).
    #[must_use = "a refused submit must be failed back to the client"]
    pub fn submit(&self, p: Pending) -> Option<usize> {
        let mut st = self.locked();
        if st.closed {
            return None;
        }
        st.queue.push_back(p);
        let idx = st.queue.len() - 1;
        let order = drain_order(&st.queue, Instant::now());
        let pos =
            order.iter().position(|&i| i == idx).unwrap_or(idx);
        st.queue[idx].reported_floor =
            st.queue[idx].reported_floor.min(pos);
        self.cv.notify_all();
        Some(pos)
    }

    /// Enqueue a control message for the batcher loop (wakes an idle
    /// batcher blocked in [`Scheduler::next_batch`]).
    pub fn control(&self, c: Control) {
        let mut st = self.locked();
        st.controls.push(c);
        self.cv.notify_all();
    }

    /// Drain every pending control message, FIFO.
    pub fn take_controls(&self) -> Vec<Control> {
        std::mem::take(&mut self.locked().controls)
    }

    /// Remove a still-queued request by its (conn, session id) key —
    /// cancellation before admission. Returns the plucked request.
    pub fn remove(&self, conn_id: u64, id: u64) -> Option<Pending> {
        let mut st = self.locked();
        let at = st
            .queue
            .iter()
            .position(|p| p.conn_id == conn_id && p.request.id == id)?;
        st.queue.remove(at)
    }

    /// Adjust `refresh_every` on a still-queued request. Returns false
    /// when no queued request matches (the batcher then checks slots).
    pub fn set_refresh(
        &self,
        conn_id: u64,
        id: u64,
        refresh_every: usize,
    ) -> bool {
        let mut st = self.locked();
        match st
            .queue
            .iter_mut()
            .find(|p| p.conn_id == conn_id && p.request.id == id)
        {
            Some(p) => {
                p.request.refresh_every = refresh_every;
                true
            }
            None => false,
        }
    }

    /// Close the queue: pending work still drains, but every later
    /// [`Scheduler::submit`] is refused.
    pub fn close(&self) {
        self.locked().closed = true;
        self.cv.notify_all();
    }

    /// Close the queue AND return everything still queued (graceful
    /// shutdown: the server fails these with a retryable error frame
    /// instead of serving them; in-flight slots drain normally).
    pub fn drain_close(&self) -> Vec<Pending> {
        let mut st = self.locked();
        st.closed = true;
        let dropped = st.queue.drain(..).collect();
        self.cv.notify_all();
        dropped
    }

    /// Requests currently queued (excludes admitted, in-flight work).
    pub fn len(&self) -> usize {
        self.locked().queue.len()
    }

    /// Snapshot of the queued sessions in tier-aware drain order:
    /// `(conn_id, session id, streaming?, reported position)` per
    /// entry. The reported position is the session's drain position
    /// clamped to never exceed any position previously reported for it
    /// (`accepted` frame included) — a later higher-tier arrival can
    /// push a session back in *actual* order, but the position the
    /// client sees is monotone non-increasing. The batcher diffs
    /// consecutive snapshots to emit v2 `queue` position-update frames
    /// while a session waits for admission.
    pub fn queued_sessions(&self) -> Vec<(u64, u64, bool, usize)> {
        let mut st = self.locked();
        let order = drain_order(&st.queue, Instant::now());
        order
            .into_iter()
            .enumerate()
            .map(|(pos, i)| {
                let p = &mut st.queue[i];
                p.reported_floor = p.reported_floor.min(pos);
                (p.conn_id, p.request.id, p.stream, p.reported_floor)
            })
            .collect()
    }

    /// Age in milliseconds of the oldest queued request (0 when the
    /// queue is empty) — the governor's queue-age pressure signal (the
    /// queue maximum is a conservative stand-in for the p95 wait).
    pub fn oldest_queue_ms(&self) -> f64 {
        let st = self.locked();
        let now = Instant::now();
        st.queue
            .iter()
            .map(|p| now.saturating_duration_since(p.arrived))
            .max()
            .map_or(0.0, |d| d.as_secs_f64() * 1e3)
    }

    /// True when nothing is queued.
    pub fn is_empty(&self) -> bool {
        self.locked().queue.is_empty()
    }

    /// Take the next batch (1..=batch_width requests). Blocks until at
    /// least one request is available or the queue is closed (→ None).
    /// After the first request arrives, waits up to `batch_window` for
    /// the batch to fill — the classic latency/throughput knob. A
    /// pending control message also wakes the wait and returns an
    /// EMPTY batch, so the idle batcher loops around and processes it.
    pub fn next_batch(&self) -> Option<Vec<Pending>> {
        let mut st = self.locked();
        // wait for work (or a control message)
        while st.queue.is_empty() && st.controls.is_empty() {
            if st.closed {
                return None;
            }
            // same poison policy as locked(): recover, don't wedge
            st = self.cv.wait(st).unwrap_or_else(|p| p.into_inner());
        }
        if st.queue.is_empty() {
            // woken by a control: hand the (empty) batch back so the
            // caller's loop drains the control queue without waiting
            // out the batch window
            return Some(Vec::new());
        }
        // batch-fill window
        let deadline = Instant::now() + self.batch_window;
        while st.queue.len() < self.batch_width && !st.closed {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            // same poison policy as locked(): recover, don't wedge
            let (lock, timeout) = self
                .cv
                .wait_timeout(st, deadline - now)
                .unwrap_or_else(|p| p.into_inner());
            st = lock;
            if timeout.timed_out() {
                break;
            }
        }
        let batch = drain_ordered(&mut st, self.batch_width);
        Some(group_by_prefix(batch, self.prefix_group_bytes))
    }

    /// Non-blocking tier-aware drain of up to `max` pending requests —
    /// the continuous batcher's mid-flight admission path.
    pub fn take(&self, max: usize) -> Vec<Pending> {
        let mut st = self.locked();
        let batch = drain_ordered(&mut st, max);
        drop(st);
        group_by_prefix(batch, self.prefix_group_bytes)
    }

    /// Return admission overflow to the FRONT of the queue, preserving
    /// FCFS order (the first element of `overflow` becomes the next
    /// request dequeued). Used by the batcher when it was handed more
    /// requests than it has free slots — overflow must be retried, not
    /// failed.
    pub fn requeue_front(&self, overflow: Vec<Pending>) {
        if overflow.is_empty() {
            return;
        }
        let mut st = self.locked();
        for p in overflow.into_iter().rev() {
            st.queue.push_front(p);
        }
        self.cv.notify_all();
    }

    /// Has [`Scheduler::close`] / [`Scheduler::drain_close`] run?
    pub fn is_closed(&self) -> bool {
        self.locked().closed
    }

    /// Block until a control message is pending or the queue closes.
    /// The batcher parks here when every decode slot is occupied AND
    /// paused by backpressure: new submissions cannot help (no free
    /// slot), so only a control (`Unpark` / `Cancel`) or shutdown can
    /// change anything — sleeping on the condvar instead of re-polling
    /// keeps an all-parked shard at zero CPU.
    pub fn wait_control(&self) {
        let mut st = self.locked();
        while !st.closed && st.controls.is_empty() {
            // same poison policy as locked(): recover, don't wedge
            st = self.cv.wait(st).unwrap_or_else(|p| p.into_inner());
        }
    }
}

/// Leading bytes shared by two prompts.
fn shared_prefix_bytes(a: &str, b: &str) -> usize {
    a.bytes()
        .zip(b.bytes())
        .take_while(|(x, y)| x == y)
        .count()
}

/// Stable-cluster a drained batch: each request joins the first earlier
/// group whose head shares at least `min_shared` leading prompt bytes,
/// else starts a new group. Groups keep first-arrival order and members
/// keep FCFS order within a group, so the reorder is bounded to the
/// batch at hand — nothing jumps the queue across batches.
pub fn group_by_prefix(
    batch: Vec<Pending>,
    min_shared: usize,
) -> Vec<Pending> {
    if min_shared == 0 || batch.len() < 3 {
        // with ≤ 2 requests clustering cannot change adjacency
        return batch;
    }
    let mut groups: Vec<Vec<Pending>> = Vec::new();
    for p in batch {
        let home = groups.iter().position(|g| {
            shared_prefix_bytes(&g[0].request.prompt, &p.request.prompt)
                >= min_shared
        });
        match home {
            Some(i) => groups[i].push(p),
            None => groups.push(vec![p]),
        }
    }
    groups.into_iter().flatten().collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    fn req(id: u64) -> Pending {
        req_with_prompt(id, "p")
    }

    fn req_with_prompt(id: u64, prompt: &str) -> Pending {
        req_tiered(id, prompt, super::super::protocol::Tier::Standard)
    }

    fn req_tiered(
        id: u64,
        prompt: &str,
        tier: super::super::protocol::Tier,
    ) -> Pending {
        Pending {
            request: Request {
                id,
                prompt: prompt.into(),
                strategy: "dense".into(),
                lambda: 0.5,
                density: 0.5,
                max_tokens: 4,
                refresh_every: 0,
                cache: crate::engine::prefix_cache::CacheMode::On,
                tier,
            },
            arrived: Instant::now(),
            conn_id: id,
            stream: true,
            resume_from: 0,
            degraded: false,
            reported_floor: usize::MAX,
        }
    }

    #[test]
    fn batches_up_to_width() {
        let s = Scheduler::new(2, Duration::from_millis(5));
        for i in 0..5 {
            let _ = s.submit(req(i));
        }
        let b1 = s.next_batch().unwrap();
        assert_eq!(b1.len(), 2);
        let b2 = s.next_batch().unwrap();
        assert_eq!(b2.len(), 2);
        let b3 = s.next_batch().unwrap();
        assert_eq!(b3.len(), 1);
        assert!(s.is_empty());
    }

    #[test]
    fn fcfs_order() {
        let s = Scheduler::new(4, Duration::from_millis(1));
        for i in 0..4 {
            let _ = s.submit(req(i));
        }
        let b = s.next_batch().unwrap();
        let ids: Vec<u64> = b.iter().map(|p| p.request.id).collect();
        assert_eq!(ids, vec![0, 1, 2, 3]);
    }

    #[test]
    fn close_unblocks() {
        let s = Arc::new(Scheduler::new(2, Duration::from_millis(1)));
        let s2 = Arc::clone(&s);
        let h = std::thread::spawn(move || s2.next_batch());
        std::thread::sleep(Duration::from_millis(20));
        s.close();
        assert!(h.join().unwrap().is_none());
    }

    #[test]
    fn window_fills_batch() {
        // Deterministic (submit-before-drain): both requests are queued
        // before next_batch runs, so the fill loop must gather both no
        // matter how the scheduler thread is timed. The old version
        // raced a 30 ms sleep against the window and flaked under load.
        let s = Scheduler::new(2, Duration::from_millis(200));
        let _ = s.submit(req(0));
        let _ = s.submit(req(1));
        let t0 = Instant::now();
        let b = s.next_batch().unwrap();
        assert_eq!(b.len(), 2, "full batch forms from queued work");
        assert!(
            t0.elapsed() < Duration::from_millis(200),
            "a full batch must not wait out the window"
        );
    }

    #[test]
    fn window_times_out_on_partial_batch() {
        // One queued request + a tiny window: next_batch returns the
        // partial batch after the window, without external signals.
        let s = Scheduler::new(4, Duration::from_millis(5));
        let _ = s.submit(req(0));
        let b = s.next_batch().unwrap();
        assert_eq!(b.len(), 1);
    }

    #[test]
    fn take_is_nonblocking_fcfs() {
        let s = Scheduler::new(4, Duration::from_millis(1));
        assert!(s.take(3).is_empty(), "empty queue → empty, no block");
        for i in 0..5 {
            let _ = s.submit(req(i));
        }
        let a = s.take(2);
        assert_eq!(
            a.iter().map(|p| p.request.id).collect::<Vec<_>>(),
            vec![0, 1]
        );
        let b = s.take(10);
        assert_eq!(
            b.iter().map(|p| p.request.id).collect::<Vec<_>>(),
            vec![2, 3, 4]
        );
        assert!(s.is_empty());
    }

    #[test]
    fn park_controls_share_the_session_key() {
        let park = Control::Park { conn_id: 7, id: 3 };
        let unpark = Control::Unpark { conn_id: 7, id: 3 };
        assert_eq!(park.key(), (7, 3));
        assert_eq!(unpark.key(), (7, 3));
        let s = Scheduler::new(2, Duration::from_millis(1));
        s.control(park);
        s.control(unpark);
        assert_eq!(s.take_controls(), vec![park, unpark], "FIFO drain");
    }

    #[test]
    fn queued_sessions_snapshot_tracks_positions() {
        let s = Scheduler::new(2, Duration::from_millis(1));
        assert!(s.queued_sessions().is_empty());
        for i in 1..=3 {
            let _ = s.submit(req(i));
        }
        assert_eq!(
            s.queued_sessions(),
            vec![(1, 1, true, 0), (2, 2, true, 1), (3, 3, true, 2)],
            "queue order, conn/session keys, stream flags, positions"
        );
        let _ = s.take(1);
        assert_eq!(
            s.queued_sessions(),
            vec![(2, 2, true, 0), (3, 3, true, 1)],
            "positions shift down as the head drains"
        );
        let _ = s.remove(2, 2);
        assert_eq!(s.queued_sessions(), vec![(3, 3, true, 0)]);
    }

    #[test]
    fn requeue_front_preserves_fcfs() {
        let s = Scheduler::new(4, Duration::from_millis(1));
        for i in 0..5 {
            let _ = s.submit(req(i));
        }
        // batcher takes 4, can only seat 2, pushes [2, 3] back
        let mut batch = s.take(4);
        let overflow: Vec<Pending> = batch.drain(2..).collect();
        s.requeue_front(overflow);
        let order: Vec<u64> = s
            .take(10)
            .iter()
            .map(|p| p.request.id)
            .collect();
        assert_eq!(order, vec![2, 3, 4], "overflow re-queued ahead, in order");
        s.requeue_front(Vec::new()); // no-op
        assert!(s.is_empty());
    }

    #[test]
    fn take_zero_and_closed_flag() {
        let s = Scheduler::new(2, Duration::from_millis(1));
        let _ = s.submit(req(0));
        assert!(s.take(0).is_empty());
        assert_eq!(s.len(), 1);
        assert!(!s.is_closed());
        s.close();
        assert!(s.is_closed());
        // closed but non-empty: queued work still drains
        assert_eq!(s.take(5).len(), 1);
    }

    #[test]
    fn prefix_grouping_clusters_without_reordering_groups() {
        let sys = "SYSTEM: you are a terse assistant. ";
        let batch = vec![
            req_with_prompt(0, &format!("{sys}alpha")),
            req_with_prompt(1, "unrelated prompt one"),
            req_with_prompt(2, &format!("{sys}beta")),
            req_with_prompt(3, "unrelated prompt two"),
            req_with_prompt(4, &format!("{sys}gamma")),
        ];
        let out = group_by_prefix(batch, 16);
        let ids: Vec<u64> = out.iter().map(|p| p.request.id).collect();
        // shared-prefix requests cluster behind their first arrival;
        // "unrelated prompt one/two" also share ≥ 16 bytes → one group
        assert_eq!(ids, vec![0, 2, 4, 1, 3]);
    }

    #[test]
    fn prefix_grouping_disabled_or_tiny_batch_is_identity() {
        let mk = || {
            vec![
                req_with_prompt(0, "aaaa bbbb"),
                req_with_prompt(1, "cccc dddd"),
                req_with_prompt(2, "aaaa eeee"),
            ]
        };
        let ids = |v: Vec<Pending>| -> Vec<u64> {
            v.iter().map(|p| p.request.id).collect()
        };
        assert_eq!(ids(group_by_prefix(mk(), 0)), vec![0, 1, 2]);
        // two-element batches are returned untouched
        let two = vec![
            req_with_prompt(7, "aaaa"),
            req_with_prompt(8, "bbbb"),
        ];
        assert_eq!(ids(group_by_prefix(two, 2)), vec![7, 8]);
        // nothing shares 6+ bytes here ("aaaa " vs "aaaa e" diverge at 5)
        assert_eq!(ids(group_by_prefix(mk(), 6)), vec![0, 1, 2]);
        // at 4 bytes the two aaaa prompts cluster
        assert_eq!(ids(group_by_prefix(mk(), 4)), vec![0, 2, 1]);
    }

    #[test]
    fn scheduler_applies_grouping_on_drain() {
        let s = Scheduler::new(8, Duration::from_millis(1))
            .with_prefix_grouping(4);
        for (i, p) in ["sys a", "solo x", "sys b", "sys c"]
            .iter()
            .enumerate()
        {
            let _ = s.submit(req_with_prompt(i as u64, p));
        }
        let ids: Vec<u64> = s
            .take(8)
            .iter()
            .map(|p| p.request.id)
            .collect();
        assert_eq!(ids, vec![0, 2, 3, 1]);
    }

    #[test]
    fn submit_returns_queue_position() {
        let s = Scheduler::new(4, Duration::from_millis(1));
        assert_eq!(s.submit(req(0)), Some(0));
        assert_eq!(s.submit(req(1)), Some(1));
        let _ = s.take(1);
        // position is relative to the live queue, not an absolute count
        assert_eq!(s.submit(req(2)), Some(1));
    }

    #[test]
    fn remove_plucks_queued_request_by_conn_and_id() {
        let s = Scheduler::new(4, Duration::from_millis(1));
        for i in 0..3 {
            let _ = s.submit(req(i)); // conn_id == id == i
        }
        let plucked = s.remove(1, 1).expect("queued request removed");
        assert_eq!(plucked.request.id, 1);
        // wrong conn or already-removed id: None, queue untouched
        assert!(s.remove(9, 2).is_none());
        assert!(s.remove(1, 1).is_none());
        let left: Vec<u64> =
            s.take(10).iter().map(|p| p.request.id).collect();
        assert_eq!(left, vec![0, 2], "FCFS order preserved around removal");
    }

    #[test]
    fn set_refresh_updates_queued_request_only() {
        let s = Scheduler::new(4, Duration::from_millis(1));
        let _ = s.submit(req(0));
        assert!(s.set_refresh(0, 0, 7));
        assert!(!s.set_refresh(0, 99, 7), "unknown id is a miss");
        let b = s.take(1);
        assert_eq!(b[0].request.refresh_every, 7);
    }

    #[test]
    fn drain_close_returns_queued_and_closes() {
        let s = Scheduler::new(4, Duration::from_millis(1));
        for i in 0..3 {
            let _ = s.submit(req(i));
        }
        let dropped = s.drain_close();
        assert_eq!(dropped.len(), 3);
        assert!(s.is_closed());
        assert!(s.is_empty());
        assert!(s.next_batch().is_none());
        // a submit racing past the shutdown check is REFUSED, never
        // silently stranded in a queue nothing will drain again
        assert_eq!(s.submit(req(9)), None);
        assert!(s.is_empty());
    }

    #[test]
    fn pending_control_wakes_idle_next_batch_with_empty_batch() {
        let s = Arc::new(Scheduler::new(2, Duration::from_millis(200)));
        let s2 = Arc::clone(&s);
        let h = std::thread::spawn(move || s2.next_batch());
        std::thread::sleep(Duration::from_millis(20));
        s.control(Control::Cancel { conn_id: 1, id: 2 });
        let batch = h.join().unwrap().expect("woken, not closed");
        assert!(batch.is_empty(), "control wake returns an empty batch");
        let controls = s.take_controls();
        assert_eq!(controls, vec![Control::Cancel { conn_id: 1, id: 2 }]);
        assert!(s.take_controls().is_empty(), "drained exactly once");
        assert_eq!(
            Control::SetRefresh { conn_id: 3, id: 4, refresh_every: 8 }
                .key(),
            (3, 4)
        );
    }

    #[test]
    fn wait_control_blocks_until_control_or_close() {
        // a control wakes the wait; queued work alone does NOT (the
        // batcher only calls this when no free slot could accept it)
        let s = Arc::new(Scheduler::new(2, Duration::from_millis(1)));
        let _ = s.submit(req(0));
        let s2 = Arc::clone(&s);
        let h = std::thread::spawn(move || {
            s2.wait_control();
            s2.take_controls()
        });
        std::thread::sleep(Duration::from_millis(20));
        s.control(Control::Unpark { conn_id: 0, id: 0 });
        let drained = h.join().unwrap();
        assert_eq!(drained, vec![Control::Unpark { conn_id: 0, id: 0 }]);
        assert_eq!(s.len(), 1, "queued work untouched by the wait");
        // and close() alone also releases the wait
        let s2 = Arc::clone(&s);
        let h = std::thread::spawn(move || s2.wait_control());
        std::thread::sleep(Duration::from_millis(20));
        s.close();
        h.join().unwrap();
    }

    #[test]
    fn drain_order_is_tier_then_fcfs() {
        use super::super::protocol::Tier;
        let s = Scheduler::new(8, Duration::from_millis(1));
        let subs = [
            (0, Tier::Batch),
            (1, Tier::Interactive),
            (2, Tier::Standard),
            (3, Tier::Interactive),
            (4, Tier::Batch),
        ];
        for (id, tier) in subs {
            let _ = s.submit(req_tiered(id, "p", tier));
        }
        let ids: Vec<u64> =
            s.take(10).iter().map(|p| p.request.id).collect();
        assert_eq!(
            ids,
            vec![1, 3, 2, 0, 4],
            "interactive first, FCFS within each tier"
        );
    }

    #[test]
    fn submit_position_reflects_tier_aware_drain_order() {
        use super::super::protocol::Tier;
        let s = Scheduler::new(8, Duration::from_millis(1));
        assert_eq!(s.submit(req_tiered(0, "p", Tier::Batch)), Some(0));
        assert_eq!(
            s.submit(req_tiered(1, "p", Tier::Interactive)),
            Some(0),
            "interactive jumps ahead of queued batch work"
        );
        assert_eq!(
            s.submit(req_tiered(2, "p", Tier::Batch)),
            Some(2),
            "batch queues behind both"
        );
    }

    #[test]
    fn reported_positions_never_grow_when_higher_tier_arrives() {
        use super::super::protocol::Tier;
        let s = Scheduler::new(8, Duration::from_millis(1));
        let _ = s.submit(req_tiered(1, "p", Tier::Standard));
        let _ = s.submit(req_tiered(2, "p", Tier::Standard));
        assert_eq!(
            s.queued_sessions(),
            vec![(1, 1, true, 0), (2, 2, true, 1)]
        );
        // an interactive arrival reorders the ACTUAL drain, but the
        // standard sessions' reported positions must not grow
        let _ = s.submit(req_tiered(3, "p", Tier::Interactive));
        assert_eq!(
            s.queued_sessions(),
            vec![(3, 3, true, 0), (1, 1, true, 0), (2, 2, true, 1)],
            "clamped: session 1 reports 0 (not 1), session 2 reports 1 \
             (not 2)"
        );
        // draining the interactive one restores truthful positions
        let ids: Vec<u64> =
            s.take(1).iter().map(|p| p.request.id).collect();
        assert_eq!(ids, vec![3]);
        assert_eq!(
            s.queued_sessions(),
            vec![(1, 1, true, 0), (2, 2, true, 1)]
        );
    }

    #[test]
    fn aged_batch_request_is_promoted_past_interactive() {
        use super::super::protocol::Tier;
        let s = Scheduler::new(8, Duration::from_millis(1));
        let mut old = req_tiered(0, "p", Tier::Batch);
        let Some(back) = Instant::now().checked_sub(
            Duration::from_millis(2 * STARVATION_PROMOTE_MS + 50),
        ) else {
            return; // cannot back-date Instant on this platform
        };
        old.arrived = back;
        let _ = s.submit(old);
        let _ = s.submit(req_tiered(1, "p", Tier::Interactive));
        let ids: Vec<u64> =
            s.take(10).iter().map(|p| p.request.id).collect();
        assert_eq!(
            ids,
            vec![0, 1],
            "a 2×-promoted batch request ranks interactive and wins \
             FCFS — no starvation"
        );
    }

    #[test]
    fn oldest_queue_ms_tracks_the_stalest_entry() {
        let s = Scheduler::new(8, Duration::from_millis(1));
        assert_eq!(s.oldest_queue_ms(), 0.0, "empty queue → 0");
        let mut p = req(0);
        if let Some(back) =
            Instant::now().checked_sub(Duration::from_millis(300))
        {
            p.arrived = back;
        }
        let _ = s.submit(p);
        let _ = s.submit(req(1));
        assert!(
            s.oldest_queue_ms() >= 290.0,
            "max age over the queue: {}",
            s.oldest_queue_ms()
        );
    }

    #[test]
    fn next_batch_drains_queued_work_after_close() {
        let s = Scheduler::new(2, Duration::from_millis(1));
        for i in 0..3 {
            let _ = s.submit(req(i));
        }
        s.close();
        assert_eq!(s.next_batch().unwrap().len(), 2);
        assert_eq!(s.next_batch().unwrap().len(), 1);
        assert!(s.next_batch().is_none());
    }
}
