//! Continuous batcher: the serving engine loop.
//!
//! A fixed-width decode batch (one compiled `decode_b{W}` executable)
//! runs step by step; each slot holds an independent in-flight request
//! ([`DecodeSession`]). Every step the batcher:
//!
//!  1. **admits** queued requests into free slots — each admission
//!     first consults the **shared-prefix cache**
//!     ([`PrefixCache`](crate::engine::prefix_cache)): an exact
//!     full-prompt hit splices cached KV + statistics + logits and
//!     skips prefill entirely; a partial hit resumes a chunked stream
//!     after the cached prefix. Cold short prompts run a monolithic
//!     batched prefill and their KV planes are spliced into the
//!     in-flight batch cache (slot surgery,
//!     [`KvState::copy_slot_from`]); a *long* prompt claims its slot
//!     but **streams in chunk by chunk** ([`ChunkedPrefill`]), at most
//!     [`Batcher::chunk_budget`] prefill chunks per decode step, so the
//!     other slots keep emitting tokens while the newcomer's prompt
//!     loads (no full-batch prefill stall). Completed-chunk prefixes
//!     (and every cold short prompt) are **published back** into the
//!     cache; a same-prefix admission arriving while a publisher is
//!     still streaming is deferred so a burst pays its miss once. The
//!     GLASS mask is built only once the final chunk lands, from the
//!     chunk-merged statistics — identical to what a monolithic
//!     prefill would have produced.
//!     Requests the engine cannot hold (`prompt + max_tokens` beyond the
//!     KV window) get an immediate error — prompts are **never silently
//!     truncated**. Admissions beyond the free-slot count are returned
//!     to the caller for FCFS re-queuing, not failed;
//!  2. **decodes** one token for every active slot through the shared
//!     masked step executable (per-slot masks, so strategies mix);
//!  3. **refreshes** masks whose request asked for it: every R decoded
//!     tokens the GLASS selection is re-run on blended prompt +
//!     decaying-average decode statistics (the paper's global-local
//!     aggregation applied over the generation horizon, not just the
//!     prompt);
//!  4. **retires** finished slots immediately — the response leaves as
//!     soon as its request stops, while longer requests keep decoding.
//!
//! Compared to the old drain-a-batch/fused-generate loop there is no
//! head-of-line blocking: a short request admitted next to a long one
//! completes and frees its slot mid-flight, and a multi-chunk prompt
//! admission never pauses in-flight decoding (`overlap_steps` counts
//! the decode steps that ran concurrently with prefill streaming).
//!
//! # Event emission (protocol v2)
//!
//! The batcher reports per-slot progress as a stream of
//! [`Event`]s through its sink instead of one whole response: a
//! `delta` per decoded text chunk (UTF-8-safe incremental decoding via
//! [`DeltaEmitter`] — the concatenation of a session's deltas is
//! byte-identical to its final text), a `refresh` per GLASS mask
//! re-aggregation, and exactly one terminal `done`/`error`. The v1
//! compatibility shim ([`Event::into_response`]) collapses this stream
//! back to the classic single response line, so the blocking protocol
//! is served bit-identically — and a non-streaming session
//! (`Pending::stream == false`, the v1 path) skips delta/refresh
//! emission entirely, so one-shot requests pay no per-token event
//! cost on the decode hot path. A **resumed** session
//! (`Pending::resume_from > 0`, the v2 `resume` frame) is admitted
//! exactly like a generate — same cache lookup, same decode — but
//! deltas the client already received are suppressed at emission, so
//! the reconnected stream continues with the original indices and the
//! delta concatenation stays byte-identical to the uninterrupted
//! stream (greedy decode on deterministic executables regenerates the
//! same tokens).
//!
//! # Cancellation and live knobs
//!
//! [`Control`] messages ride the scheduler's control queue and are
//! drained at the top of every loop iteration
//! ([`Batcher::apply_controls`]): a `Cancel` frees the target's decode
//! slot **within one decode step** (terminal `done` with finish
//! "cancel", tokens decoded so far; a still-queued target is plucked
//! from the scheduler) and re-queues nothing — the freed slot admits
//! the next queued request on the very next iteration. A `SetRefresh`
//! adjusts `refresh_every` for a live (or still-queued) session
//! mid-stream. A control whose (conn, id) matches nothing is silently
//! dropped: it means the session terminated while the control was in
//! flight, and its real terminal event is already ahead in the
//! connection's channel — emitting an error here would break the
//! exactly-one-terminal-per-session guarantee. (Controls for ids the
//! server never saw are answered with a no-op error frame by the
//! reactor before they reach the batcher.)
//!
//! # Backpressure (`Park` / `Unpark`)
//!
//! When a client stops reading and its bounded write buffer crosses
//! the high-water mark, the reactor sends a `Park` per live session on
//! that connection instead of disconnecting it. A parked **decoding**
//! slot keeps its KV, emitter state, and FCFS position but takes no
//! decode progress (its lane rides along in the batched step
//! idempotently; the logits are discarded), so the `Unpark` that
//! follows once the buffer drains below the low-water mark resumes a
//! stream that is **byte-identical** to one that never paused. A
//! still-**prefilling** parked session keeps streaming its prompt in —
//! prefill pushes no frames to the stalled client — and starts its
//! decode paused; a still-**queued** one is marked and admitted
//! paused. If every occupied slot is parked the run loop blocks on the
//! scheduler (zero CPU) rather than spinning, and a scheduler close
//! lifts all parks so shutdown drain cannot deadlock.
//! `backpressure_pauses` counts the parks that took effect (the
//! bench's slow-consumer floor).
//!
//! # Load governance (admission-time knob rewrite)
//!
//! When a [`Governor`](super::governor::Governor) is attached
//! ([`Batcher::attach_governor`]), the run loop feeds it one pressure
//! observation per iteration (queue depth, occupancy, oldest queue
//! age) and every admission maps its requested `density` /
//! `refresh_every` through [`Governor::plan`](
//! super::governor::Governor::plan) for its SLO tier **before any
//! engine work** — the governor changes *which* knob values a request
//! runs with, never the decode math, so a degraded request is
//! bit-identical to the same request sent explicitly with the degraded
//! values. The applied values surface in the terminal `done` frame as
//! `degraded` + `effective_density`.

use std::collections::{HashMap, HashSet};
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, MutexGuard};
use std::time::Instant;

use anyhow::{bail, Result};

use crate::engine::chunked::ChunkedPrefill;
use crate::engine::prefix_cache::{
    seed_to_prefill_result, CacheTelemetry, PrefixCache, PrefixHit,
};
use crate::engine::prefix_store;
use crate::engine::session::{DecodeSession, FinishReason};
use crate::engine::{Engine, KvState};
use crate::glass::{
    build_mask, refresh_mask, GlobalPrior, ImportanceMap, MaskSet,
    PriorKind, Strategy,
};
use crate::info;
use crate::tensor::TensorF;

use super::governor::Governor;
use super::protocol::{Event, Response};
use super::scheduler::{Control, Pending, Scheduler};

/// Poison-recovering lock on a shared prefix cache: a thread that
/// panicked mid-operation must not wedge the shard (the cache's
/// invariants hold at every panic point — worst case an entry's pin
/// leaks, which only exempts it from eviction). Shared with the
/// cross-shard steal path ([`super::steal::replicate_prefix`]).
pub(crate) fn lock_cache(
    cache: &Mutex<PrefixCache>,
) -> MutexGuard<'_, PrefixCache> {
    cache.lock().unwrap_or_else(|poisoned| {
        crate::warn_!("prefix cache mutex poisoned; recovering");
        poisoned.into_inner()
    })
}

/// Live occupancy gauges for one batcher (= one serving shard),
/// published by the [`Batcher::run`] loop and read lock-free by the
/// reactor threads answering the `stats` protocol command — so an
/// operator sees per-shard queue depth and slot occupancy without a
/// round trip through the engine loop.
///
/// Both gauges are packed into ONE atomic word (active in the low 32
/// bits, prefilling in the high 32) and published with a single store,
/// so any snapshot is a mutually consistent pair: a stats call racing
/// heavy admission can never observe `active + prefilling` above the
/// batch width, and never mixes a pre-retire active count with a
/// post-admit prefilling count.
#[derive(Debug, Default)]
pub struct ShardGauges {
    packed: AtomicU64,
}

impl ShardGauges {
    /// Publish both gauges atomically (one store).
    pub fn publish(&self, active: u64, prefilling: u64) {
        self.packed
            .store(active | (prefilling << 32), Ordering::Relaxed);
    }

    /// One consistent (active, prefilling) pair (one load).
    pub fn snapshot(&self) -> (u64, u64) {
        let v = self.packed.load(Ordering::Relaxed);
        (v & 0xffff_ffff, v >> 32)
    }

    /// Requests currently holding a batch slot.
    pub fn active(&self) -> u64 {
        self.snapshot().0
    }

    /// Requests currently in chunked prefill.
    pub fn prefilling(&self) -> u64 {
        self.snapshot().1
    }
}

/// Incremental, UTF-8-safe text emitter for one decode slot: turns the
/// append-only generated-token byte stream into `delta` chunks whose
/// concatenation is byte-identical to the final decoded text. Chunks
/// end only where the UTF-8 decoder's state is finalized — after a
/// valid character or after a definitively-invalid maximal subsequence
/// (flushed as U+FFFD immediately; later bytes cannot change it) —
/// while a possibly-incomplete trailing sequence is held back until
/// more bytes arrive or the stream finishes. Splitting at finalized
/// boundaries never changes the lossy decoding of what follows, so the
/// stream totals exactly `Engine::decode_text`'s lossy decode of the
/// whole sequence.
#[derive(Debug, Clone, Default)]
pub struct DeltaEmitter {
    /// Generated tokens already covered by emitted deltas.
    sent: usize,
    /// Deltas emitted so far (the next delta's contiguous index).
    deltas: u64,
}

impl DeltaEmitter {
    /// Next delta chunk for the tokens generated so far, or None when
    /// nothing new is safely emittable. `finishing` flushes the held
    /// tail (lossily, for invalid UTF-8) so the stream totals exactly
    /// the final text.
    pub fn chunk(
        &mut self,
        generated: &[i32],
        finishing: bool,
    ) -> Option<(u64, String)> {
        debug_assert!(
            generated.iter().all(|&t| (0..256).contains(&t)),
            "generated stream must be byte tokens"
        );
        let tail: Vec<u8> = generated[self.sent.min(generated.len())..]
            .iter()
            .map(|&t| t as u8)
            .collect();
        if tail.is_empty() {
            return None;
        }
        let upto = if finishing {
            tail.len()
        } else {
            // emit through every finalized region: valid runs AND
            // definitively-invalid subsequences (`error_len` is Some —
            // later bytes cannot change their decoding, so flushing
            // them lossily preserves the concat identity). Only a
            // possibly-incomplete trailing sequence (`error_len` is
            // None) is held back — a single bad byte must not stall
            // the rest of the stream until the terminal flush.
            let mut upto = 0;
            loop {
                match std::str::from_utf8(&tail[upto..]) {
                    Ok(_) => break tail.len(),
                    Err(e) => {
                        upto += e.valid_up_to();
                        match e.error_len() {
                            Some(bad) => upto += bad,
                            None => break upto,
                        }
                    }
                }
            }
        };
        if upto == 0 {
            return None;
        }
        let text = String::from_utf8_lossy(&tail[..upto]).into_owned();
        self.sent += upto;
        let index = self.deltas;
        self.deltas += 1;
        Some((index, text))
    }
}

/// Decay of the per-step decode-statistics average (per further step).
pub const STAT_DECAY: f64 = 0.9;
/// Pseudo-step mass of the prompt statistics in the refresh blend.
pub const PROMPT_STAT_WEIGHT: f64 = 1.0;

/// Admission-time facts that ride along to the finished response.
#[derive(Debug, Clone, Copy, Default)]
struct AdmitInfo {
    prefill_ms: f64,
    queue_ms: f64,
    /// Prompt tokens spliced from the shared-prefix cache.
    cached_prompt_tokens: usize,
    /// Cache entries this request used (0 or 1).
    cache_hits: usize,
    /// Entries this request's own publishes evicted.
    cache_evictions: usize,
    /// The governor rewrote this request's knobs at admission.
    degraded: bool,
}

struct Slot {
    pending: Pending,
    sess: DecodeSession,
    strategy: Strategy,
    prior_key: Option<&'static str>,
    admit: AdmitInfo,
    decode_started: Instant,
    /// Incremental delta-text state (protocol v2 streaming).
    emitter: DeltaEmitter,
    /// Parked by backpressure ([`Control::Park`]): the slot keeps its
    /// KV, emitter, and FCFS position, but takes no decode progress
    /// until [`Control::Unpark`] — the lane still rides along in the
    /// batched step (same token, same position; the write is
    /// idempotent and the logits are discarded), so resuming is
    /// byte-identical to never having paused.
    paused: bool,
}

/// A newcomer whose long prompt is still streaming in: it owns its
/// decode slot (capacity accounting + FCFS order) but takes no decode
/// steps until the final chunk lands and its mask is built.
struct Streaming {
    pending: Pending,
    strategy: Strategy,
    prior_key: Option<&'static str>,
    chunks: ChunkedPrefill,
    admit: AdmitInfo,
    /// Publish completed-chunk prefixes into the cache (mode `on`).
    publish: bool,
    /// Pinned cache entry this stream resumed from (released when the
    /// stream completes or dies — eviction skips pinned entries).
    pin: Option<usize>,
    /// Admission order — chunk scheduling is FCFS across streams.
    seq: u64,
}

enum SlotState {
    Empty,
    /// Prompt streaming in via chunked prefill.
    Prefilling(Streaming),
    /// Decoding one token per step.
    Active(Slot),
}

impl SlotState {
    fn is_empty(&self) -> bool {
        matches!(self, SlotState::Empty)
    }
}

/// Continuous-batching engine loop over step-mode decode.
pub struct Batcher {
    engine: Engine,
    /// Compiled decode width (slot count).
    pub width: usize,
    priors: HashMap<&'static str, GlobalPrior>,
    kv: KvState,
    slots: Vec<SlotState>,
    /// Packed [W, L, m] mask tensor for the decode step, kept in sync
    /// incrementally (admission / refresh / retirement) instead of
    /// being rebuilt every token — masks rarely change between steps.
    /// Free and still-prefilling slots hold dense rows (harmless; their
    /// logits are ignored).
    mask_t: TensorF,
    /// Max prefill chunks advanced per decode step (the per-step
    /// admission budget; clamped to ≥ 1). 1 = a long prompt costs each
    /// decode step at most one extra chunk of prefill work.
    pub chunk_budget: usize,
    /// Whether the manifest provides the chunked-prefill executable
    /// (old artifact bundles may not; long prompts are then rejected
    /// at admission instead of failing server startup).
    chunking: bool,
    /// Shared-prefix cache (None = disabled, `cache_bytes: 0`). Behind
    /// a mutex ONLY for the admission-time cross-shard steal path
    /// ([`super::steal::replicate_prefix`] imports a sibling's hot
    /// prefix through [`Batcher::cache_handle`]); the engine loop is
    /// still the only per-token user, and every lock here is scoped to
    /// one cache call — never held across engine or I/O work.
    cache: Option<Arc<Mutex<PrefixCache>>>,
    /// Overload governor (None = ungoverned) + this batcher's shard
    /// index into it. See [`Batcher::attach_governor`].
    governor: Option<Arc<Governor>>,
    /// This shard's index (governor observations and counters).
    shard_id: usize,
    /// Persistent snapshot file (`--cache-dir`); see
    /// [`Batcher::snapshot_hot`].
    snapshot_path: Option<PathBuf>,
    /// Defer a same-prefix admission while an earlier request is still
    /// streaming (and publishing) that prefix, so a burst of shared
    /// prompts pays the prefill miss once.
    group_prefixes: bool,
    /// Server-level aggregate cache counters (shared with the `stats`
    /// protocol command).
    telemetry: Arc<CacheTelemetry>,
    /// Live slot-occupancy gauges (shared with the `stats` command).
    gauges: Arc<ShardGauges>,
    /// Sessions parked by backpressure before (or while) they hold a
    /// decode slot: a [`Control::Park`] for a queued or still-prefilling
    /// session lands here, and [`Batcher::place`] starts the slot
    /// paused if its key is present. Cleared by `Unpark`, `Cancel`, or
    /// shutdown drain.
    parked: HashSet<(u64, u64)>,
    /// Last queue position pushed per streaming session (`conn_id`,
    /// `request id`) — v2 `queue` frames are emitted only when the
    /// position changes.
    last_queue_pos: HashMap<(u64, u64), usize>,
    /// Admission sequence counter (FCFS chunk scheduling).
    admit_seq: u64,
    /// Sessions newly paused by [`Control::Park`] (telemetry; the
    /// bench's slow-consumer floor).
    pub backpressure_pauses: u64,
    /// Total decode steps executed (telemetry / tests).
    pub steps: u64,
    /// Total prefill chunks executed for streaming admissions.
    pub chunks: u64,
    /// Decode steps that ran while ≥ 1 slot was still prefill-streaming
    /// — direct evidence the batch never stalls for a long admission.
    pub overlap_steps: u64,
    /// Total tokens emitted across finished requests.
    pub tokens_out: u64,
    /// Total prompt tokens served from the cache instead of being
    /// prefilled (the bench's "prefill tokens saved" observable).
    pub prefill_tokens_saved: u64,
}

pub use crate::config::compat::BatcherOptions;

/// One screened admission: the request plus its resolved strategy,
/// prior key, and (single) tokenization.
type Screened = (Pending, Strategy, Option<&'static str>, Vec<i32>);

/// Leading tokens shared by two encoded prompts.
fn shared_token_prefix(a: &[i32], b: &[i32]) -> usize {
    a.iter().zip(b).take_while(|(x, y)| x == y).count()
}

/// Terminal error event for a permanently failed request (validation,
/// capacity, mask/engine failure of this request alone).
fn err_event(id: u64, msg: String) -> Event {
    Event::Error {
        id,
        error: msg,
        retryable: false,
    }
}

/// Overwrite one slot's rows of the packed mask tensor ([W, L, m]);
/// `None` resets the slot to dense.
fn write_slot_mask(
    mask_t: &mut TensorF,
    n_layers: usize,
    m: usize,
    slot: usize,
    mask: Option<&MaskSet>,
) {
    for l in 0..n_layers {
        let base = (slot * n_layers + l) * m;
        match mask {
            Some(ms) => mask_t.data[base..base + m]
                .copy_from_slice(&ms.layer_mask(l)),
            None => mask_t.data[base..base + m].fill(1.0),
        }
    }
}

/// Map a wire strategy name to the selection rule + prior key. The
/// wildcard arm is an explicit error: a typo'd strategy must never be
/// silently served as i-GLASS.
pub fn resolve_strategy(
    name: &str,
    lambda: f64,
) -> Result<(Strategy, Option<&'static str>)> {
    Ok(match name {
        "dense" => (Strategy::Dense, None),
        "griffin" => (Strategy::LocalOnly, None),
        "global" => (Strategy::GlobalOnly, Some("a-glass")),
        "a-glass" => (Strategy::Glass { lambda }, Some("a-glass")),
        "i-glass" => (Strategy::Glass { lambda }, Some("i-glass")),
        other => bail!("unknown strategy '{other}'"),
    })
}

impl Batcher {
    /// Build the batcher with default options (shared-prefix cache on
    /// at [`crate::engine::prefix_cache::DEFAULT_CACHE_BYTES`], prefix
    /// grouping on, chunk budget 1).
    pub fn new(engine: Engine, batch_width: usize) -> Result<Batcher> {
        Batcher::with_options(engine, BatcherOptions::new(batch_width))
    }

    /// Build one shard's batcher from a
    /// [`crate::config::ServerConfig`]: the total cache budget is
    /// split evenly across shards and, when persistence is on, the
    /// snapshot file is the shard-indexed `.gpxs` under `cache_dir`
    /// (route_shard is deterministic across restarts, so shard i's
    /// file always warms the shard that will serve its prefixes).
    pub fn from_config(
        engine: Engine,
        cfg: &crate::config::ServerConfig,
        shard_id: usize,
    ) -> Result<Batcher> {
        Batcher::with_options(
            engine,
            BatcherOptions::for_shard(cfg, shard_id),
        )
    }

    /// Build the batcher: pick the decode width, load the priors, and
    /// warm every executable the loop can hit — `decode_b{W}`,
    /// `prefill_b{n}` for every admission size the scheduler can form
    /// (1..=W), and `prefill_chunk_b1` for streaming admissions — so no
    /// first request pays compile latency.
    pub fn with_options(
        engine: Engine,
        opts: BatcherOptions,
    ) -> Result<Batcher> {
        let width = engine.pick_batch(opts.batch_width)?;
        let mut priors = HashMap::new();
        for (key, kind) in [
            ("a-glass", PriorKind::ANps),
            ("i-glass", PriorKind::INps),
        ] {
            priors.insert(key, GlobalPrior::load(&engine.rt, kind)?);
        }
        let mut warmed = Vec::new();
        for n in 1..=width {
            let b = engine.pick_batch(n)?;
            if !warmed.contains(&b) {
                engine.rt.executable(&format!("prefill_b{b}"))?;
                warmed.push(b);
            }
        }
        // chunked long-prompt admission needs the prefill_chunk
        // executable; bundles built before it existed still serve
        // short prompts (long ones get an explicit error at admit)
        let chunking = engine.rt.manifest.exe("prefill_chunk_b1").is_ok();
        if chunking {
            engine.rt.executable("prefill_chunk_b1")?;
        }
        engine.rt.executable(&format!("decode_b{width}"))?;
        info!(
            "batcher ready: width {width}, warmed prefill_b{warmed:?} + \
             decode_b{width}{}",
            if chunking {
                " + prefill_chunk_b1 (long prompts enabled)"
            } else {
                " (no prefill_chunk executable — long prompts rejected)"
            }
        );
        let kv = KvState::zeros(engine.spec(), width);
        let slots = (0..width).map(|_| SlotState::Empty).collect();
        let spec = engine.spec();
        let mask_t =
            TensorF::ones(&[width, spec.n_layers, spec.ffn_m]);
        let telemetry = Arc::new(CacheTelemetry::default());
        let mut cache = if opts.cache_bytes > 0 {
            Some(PrefixCache::new(
                spec.clone(),
                opts.cache_bytes,
                Arc::clone(&telemetry),
            ))
        } else {
            None
        };
        // warm-start: import the previous run's hot entries; a damaged
        // or mismatched snapshot degrades to a cold cache, never a
        // startup failure
        if let (Some(cache), Some(path)) =
            (cache.as_mut(), opts.snapshot_path.as_deref())
        {
            match prefix_store::load(path, spec) {
                Ok(entries) => {
                    let total = entries.len();
                    let mut imported = 0usize;
                    for (tokens, seed) in entries {
                        match cache.import_seed(&tokens, seed) {
                            Ok(true) => imported += 1,
                            Ok(false) => {} // duplicate or over budget
                            Err(e) => crate::warn_!(
                                "cache snapshot {}: skipping entry \
                                 ({e})",
                                path.display()
                            ),
                        }
                    }
                    if total > 0 {
                        info!(
                            "prefix cache warm-started: {imported}/\
                             {total} entries from {}",
                            path.display()
                        );
                    }
                }
                Err(e) => crate::warn_!(
                    "cache snapshot {} unusable, starting cold: {e}",
                    path.display()
                ),
            }
        }
        Ok(Batcher {
            engine,
            width,
            priors,
            kv,
            slots,
            mask_t,
            chunk_budget: opts.chunk_budget.max(1),
            chunking,
            cache: cache.map(|c| Arc::new(Mutex::new(c))),
            governor: None,
            shard_id: 0,
            snapshot_path: opts.snapshot_path,
            group_prefixes: opts.group_prefixes,
            telemetry,
            gauges: Arc::new(ShardGauges::default()),
            parked: HashSet::new(),
            last_queue_pos: HashMap::new(),
            admit_seq: 0,
            backpressure_pauses: 0,
            steps: 0,
            chunks: 0,
            overlap_steps: 0,
            tokens_out: 0,
            prefill_tokens_saved: 0,
        })
    }

    /// Handle on the server-level aggregate cache counters (the `stats`
    /// protocol command reads these from the connection threads).
    pub fn telemetry(&self) -> Arc<CacheTelemetry> {
        Arc::clone(&self.telemetry)
    }

    /// Handle on this batcher's live occupancy gauges (published by
    /// [`Batcher::run`]; the `stats` command reads them per shard).
    pub fn gauges(&self) -> Arc<ShardGauges> {
        Arc::clone(&self.gauges)
    }

    /// Handle on this shard's shared-prefix cache, for the reactor's
    /// cross-shard steal path (`None` when the cache is disabled). Any
    /// holder must keep each lock scoped to single cache calls.
    pub fn cache_handle(&self) -> Option<Arc<Mutex<PrefixCache>>> {
        self.cache.as_ref().map(Arc::clone)
    }

    /// Attach the server's overload governor: the run loop then feeds
    /// it per-iteration pressure observations for `shard_id`, and every
    /// admission maps its knobs through [`Governor::plan`] for its SLO
    /// tier (see the "Load governance" module-doc section).
    pub fn attach_governor(
        &mut self,
        governor: Arc<Governor>,
        shard_id: usize,
    ) {
        self.governor = Some(governor);
        self.shard_id = shard_id;
    }

    /// Feed the governor one pressure observation (no-op when
    /// ungoverned or disabled — a switched-off governor stays a frozen
    /// level-0 identity). Called once per run-loop iteration, so the
    /// degradation level tracks load at decode-step granularity.
    fn observe_governor(&self, sched: &Scheduler) {
        if let Some(gov) =
            self.governor.as_ref().filter(|g| g.enabled())
        {
            gov.observe(
                self.shard_id,
                sched.len(),
                self.active(),
                self.prefilling(),
                self.width,
                sched.oldest_queue_ms(),
            );
        }
    }

    /// Publish the current slot occupancy to the shared gauges (one
    /// atomic store, so readers always see a consistent pair).
    fn publish_gauges(&self) {
        self.gauges
            .publish(self.active() as u64, self.prefilling() as u64);
    }

    /// Is the shared-prefix cache enabled?
    pub fn cache_enabled(&self) -> bool {
        self.cache.is_some()
    }

    /// Release a pinned cache entry (no-op without a pin or a cache).
    fn release_pin(&self, pin: Option<usize>) {
        if let (Some(pin), Some(cache)) = (pin, self.cache.as_ref()) {
            lock_cache(cache).release(pin);
        }
    }

    /// Write the cache's resident entries to this shard's snapshot
    /// file (see `--cache-dir`). The engine thread calls this right
    /// after [`Batcher::run`] returns — i.e. after `Server::stop` has
    /// drained every in-flight slot, so the snapshot captures the
    /// final hot set. A write failure is logged, never propagated:
    /// shutdown must succeed even on a full disk.
    pub fn snapshot_hot(&self) {
        let (Some(cache), Some(path)) =
            (self.cache.as_ref(), self.snapshot_path.as_deref())
        else {
            return;
        };
        // the guard is a temporary: dropped before the (blocking) save
        let entries = lock_cache(cache).export_hot();
        match prefix_store::save(path, self.engine.spec(), &entries) {
            Ok(()) => info!(
                "prefix cache snapshot: {} entries -> {}",
                entries.len(),
                path.display()
            ),
            Err(e) => crate::warn_!(
                "prefix cache snapshot to {} failed: {e}",
                path.display()
            ),
        }
    }

    /// Batch slots currently empty.
    pub fn free_slots(&self) -> usize {
        self.slots.iter().filter(|s| s.is_empty()).count()
    }

    /// Batch slots currently decoding a request.
    pub fn active(&self) -> usize {
        self.slots
            .iter()
            .filter(|s| matches!(s, SlotState::Active(_)))
            .count()
    }

    /// Slots occupied by a still-streaming chunked prefill.
    pub fn prefilling(&self) -> usize {
        self.slots
            .iter()
            .filter(|s| matches!(s, SlotState::Prefilling(_)))
            .count()
    }

    /// Decoding slots NOT parked by backpressure — the ones that make
    /// progress when [`Batcher::step`] runs a decode step.
    pub fn runnable_active(&self) -> usize {
        self.slots
            .iter()
            .filter(
                |s| matches!(s, SlotState::Active(slot) if !slot.paused),
            )
            .count()
    }

    /// Decoding slots currently parked by backpressure.
    pub fn paused(&self) -> usize {
        self.slots
            .iter()
            .filter(|s| matches!(s, SlotState::Active(slot) if slot.paused))
            .count()
    }

    /// Clear every backpressure park — slots and pre-admission marks
    /// alike. Called when the scheduler closes: a shutdown drain must
    /// not deadlock waiting for an `Unpark` whose reactor is already
    /// gone.
    fn unpark_all(&mut self) {
        self.parked.clear();
        for s in &mut self.slots {
            if let SlotState::Active(slot) = s {
                slot.paused = false;
            }
        }
    }

    /// Admit requests into free slots: short prompts batch-prefill and
    /// start decoding immediately; long prompts claim a slot and stream
    /// in chunk by chunk across subsequent [`Batcher::step`]s. Bad
    /// requests (unknown strategy, prompt + max_tokens beyond the KV
    /// window, mask failures) get an immediate terminal error event;
    /// `max_tokens <= 1` requests complete right here. Requests beyond
    /// the free-slot count are **returned** (FCFS order preserved) for
    /// the caller to re-queue — they are never failed.
    #[must_use = "admission overflow must be re-queued, not dropped"]
    pub fn admit(
        &mut self,
        pending: Vec<Pending>,
        sink: &mut dyn FnMut(u64, Event),
    ) -> Vec<Pending> {
        if pending.is_empty() {
            return Vec::new();
        }
        let admit_start = Instant::now();
        let spec = self.engine.spec().clone();

        // screen first; protocol-invalid requests never reach the
        // engine and never consume a slot
        let mut screened = Vec::new();
        for p in pending {
            let (strategy, prior_key) =
                match resolve_strategy(&p.request.strategy, p.request.lambda)
                {
                    Ok(s) => s,
                    Err(e) => {
                        sink(
                            p.conn_id,
                            err_event(p.request.id, e.to_string()),
                        );
                        continue;
                    }
                };
            // tokenize ONCE; both admission paths reuse the encoding
            // (chunked stream / prefill_encoded frame)
            let encoded = self.engine.tok.encode_with_bos(&p.request.prompt);
            let n_prompt = encoded.len();
            let budget_toks = p.request.max_tokens.max(1);
            // the final generated token comes from the last in-window
            // logits and needs no KV write, so exact capacity is
            // max_seq - n_prompt + 1 tokens
            if n_prompt + budget_toks > spec.max_seq + 1 {
                // the KV window cannot hold prompt + generation: reject
                // explicitly instead of silently truncating the prompt
                sink(
                    p.conn_id,
                    err_event(
                        p.request.id,
                        format!(
                            "prompt too long: {n_prompt} prompt tokens + \
                             {budget_toks} max_tokens exceeds the serving \
                             capacity of {} ({}-position KV window + 1 \
                             write-free final token)",
                            spec.max_seq + 1,
                            spec.max_seq
                        ),
                    ),
                );
                continue;
            }
            if n_prompt > spec.prefill_len && !self.chunking {
                sink(
                    p.conn_id,
                    err_event(
                        p.request.id,
                        format!(
                            "prompt of {n_prompt} tokens needs chunked \
                             prefill, but this artifact bundle has no \
                             prefill_chunk executable (rebuild artifacts)"
                        ),
                    ),
                );
                continue;
            }
            screened.push((p, strategy, prior_key, encoded));
        }

        // claim one free slot per request, FCFS; the remainder flows
        // back to the caller (re-queued at the scheduler front by
        // `run`), never shed as errors. A cache-reading request whose
        // prompt shares ≥ one prefill frame with a prefix some
        // in-flight (or just-claimed) stream is publishing is
        // *deferred* the same way — when it retries, the prefix is
        // cached and the burst's miss has been paid exactly once.
        let min_share = spec.prefill_len;
        let mut overflow = Vec::new();
        let mut claimed: Vec<(usize, Screened)> = Vec::new();
        let mut used: Vec<usize> = Vec::new();
        for item in screened {
            if self.group_prefixes
                && self.cache.is_some()
                && item.0.request.cache.reads()
            {
                // a request that would already hit the cache for at
                // least one frame gains nothing from waiting — only
                // defer when the shared prefix is still UNcached (a
                // warm burst must admit at full width, not serialize)
                let already_cached = self.cache.as_ref().is_some_and(
                    |c| lock_cache(c).peek_longest(&item.3) >= min_share,
                );
                let live_publisher = !already_cached
                    && self.slots.iter().any(|s| match s {
                        SlotState::Prefilling(st) => {
                            st.publish
                                && shared_token_prefix(
                                    st.chunks.tokens(),
                                    &item.3,
                                ) >= min_share
                        }
                        _ => false,
                    });
                let batch_publisher = !already_cached
                    && claimed.iter().any(|(_, c)| {
                        c.3.len() > spec.prefill_len
                            && c.0.request.cache.writes()
                            && self.chunking
                            && shared_token_prefix(&c.3, &item.3)
                                >= min_share
                    });
                if live_publisher || batch_publisher {
                    overflow.push(item.0);
                    continue;
                }
            }
            let slot = self
                .slots
                .iter()
                .enumerate()
                .position(|(i, s)| s.is_empty() && !used.contains(&i));
            match slot {
                Some(si) => {
                    used.push(si);
                    claimed.push((si, item));
                }
                None => overflow.push(item.0),
            }
        }

        // route each claimed request: an exact full-prompt cache hit
        // skips prefill entirely; a partial hit or a long prompt
        // streams chunk by chunk (resuming after the cached prefix);
        // the rest share one monolithic batched prefill
        let mut shorts: Vec<(
            usize,
            Pending,
            Strategy,
            Option<&'static str>,
        )> = Vec::new();
        let mut short_encoded: Vec<Vec<i32>> = Vec::new();
        for (si, (p, strategy, prior_key, encoded)) in claimed {
            let mut p = p;
            // admission-time governance: map the requested knobs
            // through the shard's degradation level for this request's
            // SLO tier, ONCE (sticky across requeues, so degradation
            // never compounds). Rewriting the request here — before
            // any engine work — is what makes a degraded request
            // bit-identical to one sent explicitly with these values.
            if let Some(gov) = &self.governor {
                if !p.degraded {
                    let plan = gov.plan(
                        self.shard_id,
                        p.request.tier,
                        p.request.density,
                        p.request.refresh_every,
                    );
                    if plan.degraded {
                        p.request.density = plan.density;
                        p.request.refresh_every = plan.refresh_every;
                        p.degraded = true;
                        gov.note_degraded(self.shard_id);
                    }
                }
            }
            let queue_ms =
                admit_start.duration_since(p.arrived).as_secs_f64() * 1e3;
            let mode = p.request.cache;
            let degraded = p.degraded;
            let mut hit: Option<PrefixHit> = match &self.cache {
                Some(cache) if mode.reads() => {
                    lock_cache(cache).lookup(&encoded)
                }
                _ => None,
            };
            // finishing a partial prefix needs the chunked executable
            if let Some(h) = &hit {
                if h.seed.len < encoded.len() && !self.chunking {
                    let id = h.id;
                    if let Some(cache) = self.cache.as_ref() {
                        lock_cache(cache).release(id);
                    }
                    hit = None;
                }
            }
            match hit {
                Some(h) if h.seed.len == encoded.len() => {
                    // exact hit: KV + stats + logits spliced, zero
                    // engine calls
                    let cached = h.seed.len;
                    let built = seed_to_prefill_result(&spec, &h.seed);
                    if let Some(cache) = self.cache.as_ref() {
                        lock_cache(cache).release(h.id);
                    }
                    match built {
                        Ok(pre) => {
                            self.prefill_tokens_saved += cached as u64;
                            let admit = AdmitInfo {
                                prefill_ms: 0.0,
                                queue_ms,
                                cached_prompt_tokens: cached,
                                cache_hits: 1,
                                cache_evictions: 0,
                                degraded: p.degraded,
                            };
                            self.place(
                                si, p, strategy, prior_key, &pre, 0,
                                admit, sink,
                            );
                        }
                        Err(e) => sink(
                            p.conn_id,
                            err_event(p.request.id, e.to_string()),
                        ),
                    }
                }
                hit => {
                    let long = encoded.len() > spec.prefill_len;
                    if hit.is_none() && !long {
                        shorts.push((si, p, strategy, prior_key));
                        short_encoded.push(encoded);
                        continue;
                    }
                    let publish =
                        self.cache.is_some() && mode.writes();
                    let (cached, pin, stream) = match hit {
                        Some(h) => (
                            h.seed.len,
                            Some(h.id),
                            self.engine.chunked_prefill_resume(
                                encoded,
                                spec.prefill_len,
                                h.seed,
                            ),
                        ),
                        None => (
                            0,
                            None,
                            self.engine.chunked_prefill_from_tokens(
                                encoded,
                                spec.prefill_len,
                            ),
                        ),
                    };
                    match stream {
                        Ok(chunks) => {
                            self.admit_seq += 1;
                            self.prefill_tokens_saved += cached as u64;
                            write_slot_mask(
                                &mut self.mask_t,
                                spec.n_layers,
                                spec.ffn_m,
                                si,
                                None,
                            );
                            self.slots[si] =
                                SlotState::Prefilling(Streaming {
                                    pending: p,
                                    strategy,
                                    prior_key,
                                    chunks,
                                    admit: AdmitInfo {
                                        prefill_ms: 0.0,
                                        queue_ms,
                                        cached_prompt_tokens: cached,
                                        cache_hits: usize::from(
                                            cached > 0,
                                        ),
                                        cache_evictions: 0,
                                        degraded,
                                    },
                                    publish,
                                    pin,
                                    seq: self.admit_seq,
                                });
                        }
                        Err(e) => {
                            self.release_pin(pin);
                            sink(
                                p.conn_id,
                                err_event(p.request.id, e.to_string()),
                            );
                        }
                    }
                }
            }
        }

        if shorts.is_empty() {
            return overflow;
        }
        let t0 = Instant::now();
        let pre = match self
            .engine
            .pick_batch(short_encoded.len())
            .and_then(|pb| {
                self.engine.prefill_encoded(short_encoded.clone(), pb)
            }) {
            Ok(pre) => pre,
            Err(e) => {
                for (_, p, ..) in shorts {
                    sink(p.conn_id, err_event(p.request.id, e.to_string()));
                }
                return overflow;
            }
        };
        let prefill_ms = t0.elapsed().as_secs_f64() * 1e3;

        for (i, (si, p, strategy, prior_key)) in
            shorts.into_iter().enumerate()
        {
            let queue_ms =
                admit_start.duration_since(p.arrived).as_secs_f64() * 1e3;
            // publish the whole prompt as a cached prefix: later
            // identical prompts exact-hit, longer ones resume from it
            let mut evictions = 0usize;
            if p.request.cache.writes() {
                if let Some(cache) = self.cache.as_ref() {
                    if let Ok(stats) =
                        ImportanceMap::from_stats(&pre.stats, i)
                    {
                        evictions = lock_cache(cache).insert(
                            &short_encoded[i],
                            &pre.kv,
                            i,
                            &stats,
                            pre.lens[i] as f64,
                            pre.logits.row(i),
                        );
                    }
                }
            }
            let admit = AdmitInfo {
                prefill_ms,
                queue_ms,
                cached_prompt_tokens: 0,
                cache_hits: 0,
                cache_evictions: evictions,
                degraded: p.degraded,
            };
            self.place(si, p, strategy, prior_key, &pre, i, admit, sink);
        }
        overflow
    }

    /// Build one prefilled request's mask + session and install it into
    /// decode slot `si` (KV slot splice included). Shared by the
    /// monolithic short-prompt path, the exact-cache-hit path, and the
    /// final chunk of a stream. Emits the prefill-seeded first token as
    /// the session's initial `delta`.
    #[allow(clippy::too_many_arguments)]
    fn place(
        &mut self,
        si: usize,
        p: Pending,
        strategy: Strategy,
        prior_key: Option<&'static str>,
        pre: &crate::engine::PrefillResult,
        pre_slot: usize,
        admit: AdmitInfo,
        sink: &mut dyn FnMut(u64, Event),
    ) {
        let spec = self.engine.spec().clone();
        let req = &p.request;
        let k = spec.budget(req.density);
        let prior = prior_key.and_then(|key| self.priors.get(key));
        let built = ImportanceMap::from_stats(&pre.stats, pre_slot)
            .and_then(|local| build_mask(&strategy, &local, prior, k));
        let mask = match built {
            Ok(m) => m,
            Err(e) => {
                sink(p.conn_id, err_event(req.id, e.to_string()));
                return;
            }
        };
        let sess = match DecodeSession::from_prefill(
            pre, pre_slot, mask, k, STAT_DECAY,
        ) {
            Ok(s) => s,
            Err(e) => {
                sink(p.conn_id, err_event(req.id, e.to_string()));
                return;
            }
        };
        self.kv.copy_slot_from(si, &pre.kv, pre_slot);
        // a Park that arrived while this session was queued or
        // prefilling takes effect the moment it starts decoding
        let paused = self
            .parked
            .contains(&(p.conn_id, p.request.id));
        let mut slot = Slot {
            pending: p,
            sess,
            strategy,
            prior_key,
            admit,
            decode_started: Instant::now(),
            emitter: DeltaEmitter::default(),
            paused,
        };
        let done_at_prefill = slot.sess.finished.is_some()
            || slot.sess.generated.len()
                >= slot.pending.request.max_tokens.max(1);
        if done_at_prefill {
            // stop token or 1-token budget: finished at prefill
            emit_delta(&mut slot, true, sink);
            let resp = finish_response(&self.engine, &slot);
            self.tokens_out += resp.tokens as u64;
            sink(slot.pending.conn_id, Event::Done(resp));
        } else {
            emit_delta(&mut slot, false, sink);
            write_slot_mask(
                &mut self.mask_t,
                spec.n_layers,
                spec.ffn_m,
                si,
                Some(&slot.sess.mask),
            );
            self.slots[si] = SlotState::Active(slot);
        }
    }

    /// Advance the oldest streaming admission by one prefill chunk
    /// (publishing the completed prefix into the shared-prefix cache
    /// when the stream's request allows it); on the final chunk, build
    /// the mask from the merged statistics and promote the slot to
    /// active decoding.
    fn advance_chunk(
        &mut self,
        si: usize,
        sink: &mut dyn FnMut(u64, Event),
    ) {
        let engine = self.engine.clone();
        let t0 = Instant::now();
        let stepped = {
            let SlotState::Prefilling(st) = &mut self.slots[si] else {
                return;
            };
            let r = engine.chunked_prefill_step(&mut st.chunks);
            if r.is_ok() {
                st.admit.prefill_ms += t0.elapsed().as_secs_f64() * 1e3;
            }
            r
        };
        let done = match stepped {
            Ok(done) => {
                self.chunks += 1;
                done
            }
            Err(e) => {
                let SlotState::Prefilling(st) =
                    std::mem::replace(&mut self.slots[si], SlotState::Empty)
                else {
                    unreachable!("checked Prefilling above");
                };
                self.release_pin(st.pin);
                sink(
                    st.pending.conn_id,
                    err_event(st.pending.request.id, e.to_string()),
                );
                return;
            }
        };
        // publish the just-completed prefix (a pure function of its
        // tokens): same-prefix requests admitted later splice it
        // instead of recomputing — including the final full prompt
        if let SlotState::Prefilling(st) = &mut self.slots[si] {
            if st.publish {
                if let Some(cache) = self.cache.as_ref() {
                    let consumed = st.chunks.consumed();
                    let evicted = lock_cache(cache).insert(
                        &st.chunks.tokens()[..consumed],
                        &st.chunks.kv,
                        0,
                        st.chunks.local_importance(),
                        st.chunks.merged_weight(),
                        st.chunks.logits(),
                    );
                    st.admit.cache_evictions += evicted;
                }
            }
        }
        if !done {
            return;
        }
        let SlotState::Prefilling(st) =
            std::mem::replace(&mut self.slots[si], SlotState::Empty)
        else {
            unreachable!("checked Prefilling above");
        };
        let Streaming {
            pending,
            strategy,
            prior_key,
            chunks,
            admit,
            publish: _,
            pin,
            seq: _,
        } = st;
        self.release_pin(pin);
        // consuming conversion: moves the stream's KV out instead of
        // cloning a full cache per admission
        let pre = match chunks.into_result() {
            Ok(pre) => pre,
            Err(e) => {
                sink(
                    pending.conn_id,
                    err_event(pending.request.id, e.to_string()),
                );
                return;
            }
        };
        self.place(si, pending, strategy, prior_key, &pre, 0, admit, sink);
    }

    /// One engine step: advance up to `chunk_budget` prefill chunks for
    /// streaming admissions, then decode one token for every active
    /// slot; finished slots respond and free immediately. Inactive slots
    /// ride along with a dense mask and a parked position (their logits
    /// are ignored).
    pub fn step(
        &mut self,
        sink: &mut dyn FnMut(u64, Event),
    ) -> Result<()> {
        let spec = self.engine.spec().clone();

        // ---- prefill-chunk phase (per-step admission budget)
        let mut budget = self.chunk_budget.max(1);
        while budget > 0 {
            let next = self
                .slots
                .iter()
                .enumerate()
                .filter_map(|(i, s)| match s {
                    SlotState::Prefilling(st) => Some((st.seq, i)),
                    _ => None,
                })
                .min()
                .map(|(_, i)| i);
            let Some(si) = next else { break };
            self.advance_chunk(si, sink);
            budget -= 1;
        }

        // ---- decode phase
        if self.runnable_active() == 0 {
            // nothing would make progress: an all-parked batch takes
            // no decode steps (Batcher::run blocks on the scheduler
            // instead of spinning here)
            return Ok(());
        }
        let streaming_now = self.prefilling();
        let mut tokens = vec![spec.pad_id; self.width];
        let mut pos = vec![0i32; self.width];
        {
            for (si, s) in self.slots.iter().enumerate() {
                if let SlotState::Active(slot) = s {
                    // parked slots ride along with their CURRENT token
                    // and position: the engine recomputes the same step
                    // (KV write at the same position with the same
                    // values — idempotent) and absorb below skips them,
                    // so their state is untouched until Unpark
                    tokens[si] = slot.sess.last_tok;
                    pos[si] = slot.sess.pos;
                }
            }
            let (logits, stats) = self.engine.decode_step(
                &mut self.kv,
                &tokens,
                &pos,
                &self.mask_t,
            )?;
            self.steps += 1;
            if streaming_now > 0 {
                self.overlap_steps += 1;
            }

            let engine = &self.engine;
            let priors = &self.priors;
            let tokens_out = &mut self.tokens_out;
            let mask_t = &mut self.mask_t;
            for (si, s) in self.slots.iter_mut().enumerate() {
                let SlotState::Active(slot) = s else { continue };
                if slot.paused {
                    continue; // parked: discard this lane's logits
                }
                let finished = slot.sess.absorb_step(
                    logits.row(si),
                    &stats,
                    si,
                    slot.pending.request.max_tokens,
                    spec.max_seq,
                )?;
                if finished {
                    emit_delta(slot, true, sink);
                    let resp = finish_response(engine, slot);
                    *tokens_out += resp.tokens as u64;
                    sink(slot.pending.conn_id, Event::Done(resp));
                    *s = SlotState::Empty;
                    write_slot_mask(
                        mask_t,
                        spec.n_layers,
                        spec.ffn_m,
                        si,
                        None,
                    );
                    continue;
                }
                emit_delta(slot, false, sink);
                let every = slot.pending.request.refresh_every;
                if every > 0 && slot.sess.generated.len() % every == 0 {
                    let prior =
                        slot.prior_key.and_then(|key| priors.get(key));
                    let blended =
                        slot.sess.blended_local(PROMPT_STAT_WEIGHT);
                    match refresh_mask(
                        &slot.strategy,
                        &blended,
                        prior,
                        slot.sess.k,
                        &slot.sess.mask,
                    ) {
                        Ok((mask, changed)) => {
                            slot.sess.refreshes += 1;
                            if changed {
                                slot.sess.mask_updates += 1;
                                slot.sess.mask = mask;
                                write_slot_mask(
                                    mask_t,
                                    spec.n_layers,
                                    spec.ffn_m,
                                    si,
                                    Some(&slot.sess.mask),
                                );
                            }
                            if slot.pending.stream {
                                sink(
                                    slot.pending.conn_id,
                                    Event::Refresh {
                                        id: slot.pending.request.id,
                                        refreshes: slot.sess.refreshes
                                            as u64,
                                        mask_updates: slot
                                            .sess
                                            .mask_updates
                                            as u64,
                                        changed,
                                    },
                                );
                            }
                        }
                        Err(e) => {
                            // the refresh is an optional optimization:
                            // degrade to the current mask and stop
                            // refreshing rather than discarding the
                            // tokens generated so far
                            crate::warn_!(
                                "request {}: mask refresh failed ({e}); \
                                 keeping current mask",
                                slot.pending.request.id
                            );
                            slot.pending.request.refresh_every = 0;
                        }
                    }
                }
            }
        }
        Ok(())
    }

    /// Abort every in-flight request with an error (engine failure) —
    /// including admissions still streaming their prompt in. These are
    /// marked retryable: the requests themselves were valid.
    pub fn fail_all(
        &mut self,
        err: &anyhow::Error,
        sink: &mut dyn FnMut(u64, Event),
    ) {
        let spec = self.engine.spec().clone();
        for (si, s) in self.slots.iter_mut().enumerate() {
            let pending = match std::mem::replace(s, SlotState::Empty) {
                SlotState::Empty => continue,
                SlotState::Prefilling(st) => {
                    if let (Some(pin), Some(cache)) =
                        (st.pin, self.cache.as_ref())
                    {
                        lock_cache(cache).release(pin);
                    }
                    st.pending
                }
                SlotState::Active(slot) => slot.pending,
            };
            sink(
                pending.conn_id,
                Event::Error {
                    id: pending.request.id,
                    error: err.to_string(),
                    retryable: true,
                },
            );
            write_slot_mask(
                &mut self.mask_t,
                spec.n_layers,
                spec.ffn_m,
                si,
                None,
            );
        }
    }

    /// Drain and apply every pending [`Control`] from the scheduler:
    /// cancels free their slot right here (terminal `done` with finish
    /// "cancel" — within one decode step of the frame's arrival) or
    /// pluck a still-queued request; `set` adjusts `refresh_every`
    /// live. A control matching no slot and no queued request is
    /// dropped — its session already terminated, and a second terminal
    /// must never be emitted.
    pub fn apply_controls(
        &mut self,
        sched: &Scheduler,
        sink: &mut dyn FnMut(u64, Event),
    ) {
        for c in sched.take_controls() {
            self.apply_control(c, sched, sink);
        }
    }

    /// Apply one control message (see [`Batcher::apply_controls`]).
    pub fn apply_control(
        &mut self,
        c: Control,
        sched: &Scheduler,
        sink: &mut dyn FnMut(u64, Event),
    ) {
        let (conn_id, id) = c.key();
        let spec = self.engine.spec().clone();
        let si = self.slots.iter().position(|s| match s {
            SlotState::Active(slot) => {
                slot.pending.conn_id == conn_id
                    && slot.pending.request.id == id
            }
            SlotState::Prefilling(st) => {
                st.pending.conn_id == conn_id && st.pending.request.id == id
            }
            SlotState::Empty => false,
        });
        match c {
            Control::Cancel { .. } => {
                // a cancelled session can never be unparked later —
                // drop any pre-admission park mark so the set stays
                // bounded by live parked sessions
                self.parked.remove(&(conn_id, id));
                let Some(si) = si else {
                    // not in a slot: maybe still queued — pluck it
                    if let Some(p) = sched.remove(conn_id, id) {
                        let mut resp = Response::ok(
                            id,
                            String::new(),
                            0,
                            0.0,
                            0.0,
                            p.request.density,
                        );
                        resp.queue_ms = p
                            .arrived
                            .elapsed()
                            .as_secs_f64()
                            * 1e3;
                        resp.degraded = p.degraded;
                        resp.finish = "cancel".to_string();
                        sink(p.conn_id, Event::Done(resp));
                    }
                    // neither slotted nor queued: the session already
                    // terminated naturally and its real terminal event
                    // is ahead of us in the connection's channel — a
                    // second (error) terminal here would break the
                    // exactly-one-terminal-per-session guarantee. The
                    // reactor answers controls for ids it has never
                    // seen; a control losing this race is dropped.
                    return;
                };
                match std::mem::replace(&mut self.slots[si], SlotState::Empty)
                {
                    SlotState::Active(mut slot) => {
                        // flush the held delta tail, then finish with
                        // the tokens decoded so far
                        emit_delta(&mut slot, true, sink);
                        let mut resp =
                            finish_response(&self.engine, &slot);
                        resp.finish = "cancel".to_string();
                        self.tokens_out += resp.tokens as u64;
                        sink(slot.pending.conn_id, Event::Done(resp));
                    }
                    SlotState::Prefilling(st) => {
                        self.release_pin(st.pin);
                        let mut resp = Response::ok(
                            id,
                            String::new(),
                            0,
                            st.admit.prefill_ms,
                            0.0,
                            st.pending.request.density,
                        );
                        resp.queue_ms = st.admit.queue_ms;
                        resp.prompt_tokens = st.chunks.consumed();
                        resp.degraded = st.admit.degraded;
                        resp.finish = "cancel".to_string();
                        sink(st.pending.conn_id, Event::Done(resp));
                    }
                    SlotState::Empty => unreachable!("matched above"),
                }
                write_slot_mask(
                    &mut self.mask_t,
                    spec.n_layers,
                    spec.ffn_m,
                    si,
                    None,
                );
            }
            Control::SetRefresh { refresh_every, .. } => {
                if let Some(si) = si {
                    match &mut self.slots[si] {
                        SlotState::Active(slot) => {
                            slot.pending.request.refresh_every =
                                refresh_every;
                        }
                        SlotState::Prefilling(st) => {
                            st.pending.request.refresh_every =
                                refresh_every;
                        }
                        SlotState::Empty => unreachable!("matched above"),
                    }
                } else {
                    // queued update, or a no-op: the session finished
                    // while the frame was in flight (same reasoning as
                    // the cancel race above — never add a terminal)
                    let _ = sched.set_refresh(conn_id, id, refresh_every);
                }
            }
            Control::Park { .. } => {
                match si.map(|si| &mut self.slots[si]) {
                    Some(SlotState::Active(slot)) => {
                        if !slot.paused {
                            slot.paused = true;
                            self.backpressure_pauses += 1;
                        }
                        // keep the mark too: a consistent picture if
                        // the slot retires oddly, and Unpark clears
                        // both unconditionally
                        self.parked.insert((conn_id, id));
                    }
                    Some(SlotState::Prefilling(_)) => {
                        // prefill keeps streaming (it pushes no frames
                        // to the stalled client); the pause lands at
                        // promotion (see Batcher::place)
                        if self.parked.insert((conn_id, id)) {
                            self.backpressure_pauses += 1;
                        }
                    }
                    _ => {
                        // still queued → pause at admission; a session
                        // that already terminated is ignored (same race
                        // rule as cancel: the mark would leak forever)
                        let queued = sched
                            .queued_sessions()
                            .iter()
                            .any(|&(c, i, _, _)| c == conn_id && i == id);
                        if queued && self.parked.insert((conn_id, id)) {
                            self.backpressure_pauses += 1;
                        }
                    }
                }
            }
            Control::Unpark { .. } => {
                self.parked.remove(&(conn_id, id));
                if let Some(si) = si {
                    if let SlotState::Active(slot) = &mut self.slots[si] {
                        slot.paused = false;
                    }
                }
            }
        }
    }

    /// Drive the loop against a scheduler until it closes and drains:
    /// block for work only when idle, admit mid-flight otherwise.
    /// Control messages (cancel / set / park / unpark) are drained at
    /// the top of every iteration, so a cancel frees its slot — and a
    /// park stops a slow consumer's decode — within one decode step.
    /// Admission overflow (more queued work than free slots) is pushed
    /// back onto the scheduler's queue front, preserving FCFS, and
    /// sessions still waiting get a v2 `queue` frame whenever their
    /// position changes. Once the scheduler closes, every park is
    /// lifted so the shutdown drain cannot deadlock on a reactor that
    /// will never send `Unpark`.
    pub fn run(
        &mut self,
        sched: &Scheduler,
        sink: &mut dyn FnMut(u64, Event),
    ) {
        loop {
            self.publish_gauges();
            self.observe_governor(sched);
            self.apply_controls(sched, sink);
            if sched.is_closed() {
                self.unpark_all();
            }
            let free = self.free_slots();
            if free > 0 {
                if self.active() == 0 && self.prefilling() == 0 {
                    // idle: block until work arrives (batch_window lets
                    // an initial burst form), or exit on close+empty
                    match sched.next_batch() {
                        Some(batch) => {
                            let over = self.admit(batch, sink);
                            sched.requeue_front(over);
                        }
                        None => break,
                    }
                } else {
                    // mid-flight admission into free slots
                    let newly = sched.take(free);
                    if !newly.is_empty() {
                        let over = self.admit(newly, sink);
                        sched.requeue_front(over);
                    }
                }
            }
            self.emit_queue_positions(sched, sink);
            if self.active() == 0 && self.prefilling() == 0 {
                continue;
            }
            if self.prefilling() == 0 && self.runnable_active() == 0 {
                // every occupied slot is parked by backpressure: a
                // decode step would do no useful work, so block
                // instead of spinning. With a free slot, new work can
                // still help → wait in next_batch (wakes on submit OR
                // control); with the width fully parked, only a
                // control or shutdown can change anything.
                if self.free_slots() == 0 {
                    sched.wait_control();
                } else {
                    match sched.next_batch() {
                        Some(batch) => {
                            let over = self.admit(batch, sink);
                            sched.requeue_front(over);
                        }
                        None => self.unpark_all(),
                    }
                }
                continue;
            }
            if let Err(e) = self.step(sink) {
                self.fail_all(&e, sink);
            }
            self.publish_gauges();
        }
        self.publish_gauges();
    }

    /// Push a v2 `queue` frame to every streaming session whose queue
    /// position changed since the last look (0 = next to be admitted).
    /// Admitted / cancelled sessions simply drop out of the tracking
    /// map. Positions come from [`Scheduler::queued_sessions`], which
    /// clamps each session's reported position to its historical floor
    /// — so even under tier-aware reordering (a later interactive
    /// arrival draining ahead of a queued batch request) a session's
    /// position never grows, and a changed position always shrinks.
    fn emit_queue_positions(
        &mut self,
        sched: &Scheduler,
        sink: &mut dyn FnMut(u64, Event),
    ) {
        if self.last_queue_pos.is_empty() && sched.is_empty() {
            return; // common case: no queue now, none last time
        }
        let mut fresh = HashMap::new();
        for (conn_id, id, stream, pos) in sched.queued_sessions() {
            if !stream {
                continue; // v1 sessions have no event channel
            }
            if self.last_queue_pos.get(&(conn_id, id)) != Some(&pos) {
                sink(
                    conn_id,
                    Event::Queue {
                        id,
                        position: pos as u64,
                    },
                );
            }
            fresh.insert((conn_id, id), pos);
        }
        self.last_queue_pos = fresh;
    }
}

/// Emit the slot's next delta chunk, if any new text is safely
/// representable (see [`DeltaEmitter`]). Non-streaming sessions (v1
/// one-shot requests) skip this entirely: their compatibility shim
/// would discard every delta, so building and sending one per token
/// would be pure hot-path overhead.
///
/// Resumed sessions (`Pending::resume_from > 0`) re-run the
/// deterministic decode from the start, so the emitter regenerates the
/// deltas the client already consumed — those (index < `resume_from`)
/// are suppressed here, AFTER the emitter's counters advance, so the
/// surviving frames carry their original indices and the client's
/// concatenation stays byte-identical to the uninterrupted stream.
fn emit_delta(
    slot: &mut Slot,
    finishing: bool,
    sink: &mut dyn FnMut(u64, Event),
) {
    if !slot.pending.stream {
        return;
    }
    if let Some((index, text)) =
        slot.emitter.chunk(&slot.sess.generated, finishing)
    {
        if index < slot.pending.resume_from {
            return;
        }
        sink(
            slot.pending.conn_id,
            Event::Delta {
                id: slot.pending.request.id,
                index,
                text,
            },
        );
    }
}

fn finish_response(engine: &Engine, slot: &Slot) -> Response {
    let sess = &slot.sess;
    let mut resp = Response::ok(
        slot.pending.request.id,
        engine.decode_text(&sess.generated),
        sess.generated.len(),
        slot.admit.prefill_ms,
        slot.decode_started.elapsed().as_secs_f64() * 1e3,
        sess.mask.density(),
    );
    resp.queue_ms = slot.admit.queue_ms;
    resp.prompt_tokens = sess.prompt_len;
    resp.cached_prompt_tokens = slot.admit.cached_prompt_tokens;
    resp.cache_hits = slot.admit.cache_hits;
    resp.cache_evictions = slot.admit.cache_evictions;
    resp.refreshes = sess.refreshes;
    resp.mask_updates = sess.mask_updates;
    resp.degraded = slot.admit.degraded;
    resp.effective_density = slot.pending.request.density;
    resp.finish = sess
        .finished
        .unwrap_or(FinishReason::Length)
        .as_str()
        .to_string();
    resp
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unknown_strategy_is_an_error_not_iglass() {
        // the old serve path had a `_ =>` arm that silently served any
        // typo as i-GLASS; the resolver must reject instead
        for bad in ["bogus", "iglass", "I-GLASS", ""] {
            let err = resolve_strategy(bad, 0.5).unwrap_err();
            assert!(
                err.to_string().contains("unknown strategy"),
                "{bad}: {err}"
            );
        }
        for good in super::super::protocol::STRATEGIES {
            assert!(resolve_strategy(good, 0.5).is_ok(), "{good}");
        }
    }

    #[test]
    fn delta_emitter_concat_equals_full_decode() {
        // ASCII: one delta per new token, concat == whole
        let mut e = DeltaEmitter::default();
        let gen: Vec<i32> = "the fox".bytes().map(|b| b as i32).collect();
        let mut out = String::new();
        for n in 1..=gen.len() {
            if let Some((i, t)) = e.chunk(&gen[..n], false) {
                assert_eq!(i as usize + 1, n, "contiguous indices");
                out.push_str(&t);
            }
        }
        assert!(e.chunk(&gen, true).is_none(), "nothing left to flush");
        assert_eq!(out, "the fox");
    }

    #[test]
    fn delta_emitter_holds_back_incomplete_utf8() {
        // "é" = [0xC3, 0xA9] split across two tokens: the first byte
        // must be held (NOT emitted as a replacement char), then both
        // emitted together — concat stays byte-identical to the lossy
        // decode of the whole stream
        let mut e = DeltaEmitter::default();
        let gen = vec![b'a' as i32, 0xC3];
        let (i0, t0) = e.chunk(&gen, false).expect("ascii prefix emits");
        assert_eq!((i0, t0.as_str()), (0, "a"));
        assert!(
            e.chunk(&gen, false).is_none(),
            "incomplete sequence held back"
        );
        let gen = vec![b'a' as i32, 0xC3, 0xA9, b'b' as i32];
        let (i1, t1) = e.chunk(&gen, false).expect("completed char emits");
        assert_eq!((i1, t1.as_str()), (1, "éb"));
    }

    #[test]
    fn delta_emitter_emits_finalized_invalid_bytes_immediately() {
        // a DEFINITIVELY invalid byte (error_len = Some) must not
        // stall the stream: it is flushed lossily right away, and the
        // concat still equals the lossy decode of the whole stream
        let mut e = DeltaEmitter::default();
        let gen = vec![b'x' as i32, 0xFF, b'y' as i32];
        let (_, t0) =
            e.chunk(&gen, false).expect("finalized region emits");
        assert_eq!(
            t0,
            String::from_utf8_lossy(&[b'x', 0xFF, b'y']).into_owned()
        );
        assert!(e.chunk(&gen, false).is_none(), "fully drained");
        assert!(e.chunk(&gen, true).is_none(), "nothing left at finish");

        // ...while a possibly-incomplete trailing sequence is still
        // held back and flushed only on finish
        let mut e = DeltaEmitter::default();
        let gen = vec![b'x' as i32, 0xE2, 0x82]; // truncated 3-byte seq
        let (_, t0) = e.chunk(&gen, false).expect("valid prefix emits");
        assert_eq!(t0, "x");
        assert!(e.chunk(&gen, false).is_none(), "incomplete tail held");
        let (_, t1) = e.chunk(&gen, true).expect("finish flushes");
        let mut concat = t0;
        concat.push_str(&t1);
        assert_eq!(
            concat,
            String::from_utf8_lossy(&[b'x', 0xE2, 0x82]).into_owned(),
            "delta concat must equal the lossy decode of the stream"
        );
    }

    #[test]
    fn gauges_snapshot_is_always_a_consistent_pair() {
        // the stats-race satellite: with both gauges packed into one
        // atomic word, a reader hammering snapshots during publishes
        // can never observe active + prefilling above the batch width
        use std::sync::atomic::AtomicBool;
        use std::sync::Arc;
        let g = Arc::new(ShardGauges::default());
        let stop = Arc::new(AtomicBool::new(false));
        let width = 4u64;
        let writer = {
            let g = Arc::clone(&g);
            let stop = Arc::clone(&stop);
            std::thread::spawn(move || {
                // cheap deterministic pseudo-random valid pairs
                let mut x = 0x2545f491u64;
                while !stop.load(Ordering::Relaxed) {
                    x ^= x << 13;
                    x ^= x >> 7;
                    x ^= x << 17;
                    let active = x % (width + 1);
                    let prefilling = (x >> 8) % (width - active + 1);
                    g.publish(active, prefilling);
                }
            })
        };
        // Miri executes this interleaving-by-interleaving; a few
        // hundred iterations already cover the race it checks for.
        let iters = if cfg!(miri) { 500 } else { 50_000 };
        for _ in 0..iters {
            let (a, p) = g.snapshot();
            assert!(
                a + p <= width,
                "inconsistent gauge pair: active {a} + prefilling {p} \
                 exceeds width {width}"
            );
        }
        stop.store(true, Ordering::Relaxed);
        writer.join().unwrap();
    }

    #[test]
    fn glass_variants_pick_matching_priors() {
        let (s, p) = resolve_strategy("a-glass", 0.25).unwrap();
        assert!(matches!(s, Strategy::Glass { lambda } if lambda == 0.25));
        assert_eq!(p, Some("a-glass"));
        let (_, p) = resolve_strategy("i-glass", 0.5).unwrap();
        assert_eq!(p, Some("i-glass"));
        let (s, p) = resolve_strategy("dense", 0.5).unwrap();
        assert!(matches!(s, Strategy::Dense));
        assert_eq!(p, None);
    }
}
