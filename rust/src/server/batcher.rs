//! Continuous batcher: the serving engine loop.
//!
//! A fixed-width decode batch (one compiled `decode_b{W}` executable)
//! runs step by step; each slot holds an independent in-flight request
//! ([`DecodeSession`]). Every step the batcher:
//!
//!  1. **admits** queued requests into free slots — prefill runs on the
//!     smallest compiled batch that fits the newcomers, and their KV
//!     planes are spliced into the in-flight batch cache (slot surgery,
//!     [`KvState::copy_slot_from`]);
//!  2. **decodes** one token for every active slot through the shared
//!     masked step executable (per-slot masks, so strategies mix);
//!  3. **refreshes** masks whose request asked for it: every R decoded
//!     tokens the GLASS selection is re-run on blended prompt +
//!     decaying-average decode statistics (the paper's global-local
//!     aggregation applied over the generation horizon, not just the
//!     prompt);
//!  4. **retires** finished slots immediately — the response leaves as
//!     soon as its request stops, while longer requests keep decoding.
//!
//! Compared to the old drain-a-batch/fused-generate loop there is no
//! head-of-line blocking: a short request admitted next to a long one
//! completes and frees its slot mid-flight.

use std::collections::HashMap;
use std::time::Instant;

use anyhow::{bail, Result};

use crate::engine::session::{DecodeSession, FinishReason};
use crate::engine::{Engine, KvState};
use crate::glass::{
    build_mask, refresh_mask, GlobalPrior, MaskSet, PriorKind, Strategy,
};
use crate::info;
use crate::tensor::TensorF;

use super::protocol::Response;
use super::scheduler::{Pending, Scheduler};

/// Decay of the per-step decode-statistics average (per further step).
pub const STAT_DECAY: f64 = 0.9;
/// Pseudo-step mass of the prompt statistics in the refresh blend.
pub const PROMPT_STAT_WEIGHT: f64 = 1.0;

struct Slot {
    pending: Pending,
    sess: DecodeSession,
    strategy: Strategy,
    prior_key: Option<&'static str>,
    prefill_ms: f64,
    queue_ms: f64,
    decode_started: Instant,
}

/// Continuous-batching engine loop over step-mode decode.
pub struct Batcher {
    engine: Engine,
    /// Compiled decode width (slot count).
    pub width: usize,
    priors: HashMap<&'static str, GlobalPrior>,
    kv: KvState,
    slots: Vec<Option<Slot>>,
    /// Packed [W, L, m] mask tensor for the decode step, kept in sync
    /// incrementally (admission / refresh / retirement) instead of
    /// being rebuilt every token — masks rarely change between steps.
    /// Free slots hold dense rows (harmless; their logits are ignored).
    mask_t: TensorF,
    /// Total decode steps executed (telemetry / tests).
    pub steps: u64,
    /// Total tokens emitted across finished requests.
    pub tokens_out: u64,
}

/// Overwrite one slot's rows of the packed mask tensor ([W, L, m]);
/// `None` resets the slot to dense.
fn write_slot_mask(
    mask_t: &mut TensorF,
    n_layers: usize,
    m: usize,
    slot: usize,
    mask: Option<&MaskSet>,
) {
    for l in 0..n_layers {
        let base = (slot * n_layers + l) * m;
        match mask {
            Some(ms) => mask_t.data[base..base + m]
                .copy_from_slice(&ms.layer_mask(l)),
            None => mask_t.data[base..base + m].fill(1.0),
        }
    }
}

/// Map a wire strategy name to the selection rule + prior key. The
/// wildcard arm is an explicit error: a typo'd strategy must never be
/// silently served as i-GLASS.
pub fn resolve_strategy(
    name: &str,
    lambda: f64,
) -> Result<(Strategy, Option<&'static str>)> {
    Ok(match name {
        "dense" => (Strategy::Dense, None),
        "griffin" => (Strategy::LocalOnly, None),
        "global" => (Strategy::GlobalOnly, Some("a-glass")),
        "a-glass" => (Strategy::Glass { lambda }, Some("a-glass")),
        "i-glass" => (Strategy::Glass { lambda }, Some("i-glass")),
        other => bail!("unknown strategy '{other}'"),
    })
}

impl Batcher {
    /// Build the batcher: pick the decode width, load the priors, and
    /// warm every executable the loop can hit — `decode_b{W}` plus
    /// `prefill_b{n}` for every admission size the scheduler can form
    /// (1..=W), so no first request pays compile latency.
    pub fn new(engine: Engine, batch_width: usize) -> Result<Batcher> {
        let width = engine.pick_batch(batch_width)?;
        let mut priors = HashMap::new();
        for (key, kind) in [
            ("a-glass", PriorKind::ANps),
            ("i-glass", PriorKind::INps),
        ] {
            priors.insert(key, GlobalPrior::load(&engine.rt, kind)?);
        }
        let mut warmed = Vec::new();
        for n in 1..=width {
            let b = engine.pick_batch(n)?;
            if !warmed.contains(&b) {
                engine.rt.executable(&format!("prefill_b{b}"))?;
                warmed.push(b);
            }
        }
        engine.rt.executable(&format!("decode_b{width}"))?;
        info!(
            "batcher ready: width {width}, warmed prefill_b{warmed:?} + \
             decode_b{width}"
        );
        let kv = KvState::zeros(engine.spec(), width);
        let slots = (0..width).map(|_| None).collect();
        let spec = engine.spec();
        let mask_t =
            TensorF::ones(&[width, spec.n_layers, spec.ffn_m]);
        Ok(Batcher {
            engine,
            width,
            priors,
            kv,
            slots,
            mask_t,
            steps: 0,
            tokens_out: 0,
        })
    }

    pub fn free_slots(&self) -> usize {
        self.slots.iter().filter(|s| s.is_none()).count()
    }

    pub fn active(&self) -> usize {
        self.width - self.free_slots()
    }

    /// Admit up to `free_slots()` requests: batch-prefill the newcomers,
    /// build their prefill-time masks, splice KV into free slots. Bad
    /// requests (unknown strategy, mask failures) get an immediate error
    /// response; `max_tokens <= 1` requests complete right here.
    pub fn admit(
        &mut self,
        pending: Vec<Pending>,
        sink: &mut dyn FnMut(u64, Response),
    ) {
        if pending.is_empty() {
            return;
        }
        let admit_start = Instant::now();
        let spec = self.engine.spec().clone();

        // resolve strategies first; protocol-invalid requests never
        // reach the engine
        let mut accepted = Vec::new();
        for p in pending {
            match resolve_strategy(&p.request.strategy, p.request.lambda) {
                Ok((strategy, prior_key)) => {
                    accepted.push((p, strategy, prior_key))
                }
                Err(e) => {
                    sink(p.conn_id, Response::err(p.request.id, e.to_string()))
                }
            }
        }
        if accepted.is_empty() {
            return;
        }
        if accepted.len() > self.free_slots() {
            // caller bug: shed the overflow back as errors rather than
            // corrupting slot state
            for (p, ..) in accepted.drain(self.free_slots()..) {
                sink(
                    p.conn_id,
                    Response::err(p.request.id, "batcher overloaded".into()),
                );
            }
        }

        let prompts: Vec<String> = accepted
            .iter()
            .map(|(p, ..)| p.request.prompt.clone())
            .collect();
        let t0 = Instant::now();
        let pre = match self
            .engine
            .pick_batch(prompts.len())
            .and_then(|pb| self.engine.prefill(&prompts, pb))
        {
            Ok(pre) => pre,
            Err(e) => {
                for (p, ..) in accepted {
                    sink(p.conn_id, Response::err(p.request.id, e.to_string()));
                }
                return;
            }
        };
        let prefill_ms = t0.elapsed().as_secs_f64() * 1e3;

        for (i, (p, strategy, prior_key)) in accepted.into_iter().enumerate()
        {
            let req = &p.request;
            let k = spec.budget(req.density);
            let prior = prior_key.and_then(|key| self.priors.get(key));
            let built = self
                .engine
                .local_importance(&pre, i)
                .and_then(|local| build_mask(&strategy, &local, prior, k));
            let mask = match built {
                Ok(m) => m,
                Err(e) => {
                    sink(p.conn_id, Response::err(req.id, e.to_string()));
                    continue;
                }
            };
            let sess = match DecodeSession::from_prefill(
                &pre, i, mask, k, STAT_DECAY,
            ) {
                Ok(s) => s,
                Err(e) => {
                    sink(p.conn_id, Response::err(req.id, e.to_string()));
                    continue;
                }
            };
            let si = self
                .slots
                .iter()
                .position(|s| s.is_none())
                .expect("free slot accounted above");
            self.kv.copy_slot_from(si, &pre.kv, i);
            let queue_ms =
                admit_start.duration_since(p.arrived).as_secs_f64() * 1e3;
            let slot = Slot {
                pending: p,
                sess,
                strategy,
                prior_key,
                prefill_ms,
                queue_ms,
                decode_started: Instant::now(),
            };
            let done_at_prefill = slot.sess.finished.is_some()
                || slot.sess.generated.len()
                    >= slot.pending.request.max_tokens.max(1);
            if done_at_prefill {
                // stop token or 1-token budget: finished at prefill
                let resp = finish_response(&self.engine, &slot);
                self.tokens_out += resp.tokens as u64;
                sink(slot.pending.conn_id, resp);
            } else {
                write_slot_mask(
                    &mut self.mask_t,
                    spec.n_layers,
                    spec.ffn_m,
                    si,
                    Some(&slot.sess.mask),
                );
                self.slots[si] = Some(slot);
            }
        }
    }

    /// One decode step for every active slot; finished slots respond and
    /// free immediately. Inactive slots ride along with a dense mask and
    /// a parked position (their logits are ignored).
    pub fn step(
        &mut self,
        sink: &mut dyn FnMut(u64, Response),
    ) -> Result<()> {
        let spec = self.engine.spec().clone();
        if self.active() == 0 {
            return Ok(());
        }
        let mut tokens = vec![spec.pad_id; self.width];
        let mut pos = vec![0i32; self.width];
        {
            for (si, s) in self.slots.iter().enumerate() {
                if let Some(slot) = s {
                    tokens[si] = slot.sess.last_tok;
                    pos[si] = slot.sess.pos;
                }
            }
            let (logits, stats) = self.engine.decode_step(
                &mut self.kv,
                &tokens,
                &pos,
                &self.mask_t,
            )?;
            self.steps += 1;

            let engine = &self.engine;
            let priors = &self.priors;
            let tokens_out = &mut self.tokens_out;
            let mask_t = &mut self.mask_t;
            for (si, s) in self.slots.iter_mut().enumerate() {
                let Some(slot) = s else { continue };
                let finished = slot.sess.absorb_step(
                    logits.row(si),
                    &stats,
                    si,
                    slot.pending.request.max_tokens,
                    spec.max_seq,
                )?;
                if finished {
                    let resp = finish_response(engine, slot);
                    *tokens_out += resp.tokens as u64;
                    sink(slot.pending.conn_id, resp);
                    *s = None;
                    write_slot_mask(
                        mask_t,
                        spec.n_layers,
                        spec.ffn_m,
                        si,
                        None,
                    );
                    continue;
                }
                let every = slot.pending.request.refresh_every;
                if every > 0 && slot.sess.generated.len() % every == 0 {
                    let prior =
                        slot.prior_key.and_then(|key| priors.get(key));
                    let blended =
                        slot.sess.blended_local(PROMPT_STAT_WEIGHT);
                    match refresh_mask(
                        &slot.strategy,
                        &blended,
                        prior,
                        slot.sess.k,
                        &slot.sess.mask,
                    ) {
                        Ok((mask, changed)) => {
                            slot.sess.refreshes += 1;
                            if changed {
                                slot.sess.mask_updates += 1;
                                slot.sess.mask = mask;
                                write_slot_mask(
                                    mask_t,
                                    spec.n_layers,
                                    spec.ffn_m,
                                    si,
                                    Some(&slot.sess.mask),
                                );
                            }
                        }
                        Err(e) => {
                            // the refresh is an optional optimization:
                            // degrade to the current mask and stop
                            // refreshing rather than discarding the
                            // tokens generated so far
                            crate::warn_!(
                                "request {}: mask refresh failed ({e}); \
                                 keeping current mask",
                                slot.pending.request.id
                            );
                            slot.pending.request.refresh_every = 0;
                        }
                    }
                }
            }
        }
        Ok(())
    }

    /// Abort every in-flight request with an error (engine failure).
    pub fn fail_all(
        &mut self,
        err: &anyhow::Error,
        sink: &mut dyn FnMut(u64, Response),
    ) {
        let spec = self.engine.spec().clone();
        for (si, s) in self.slots.iter_mut().enumerate() {
            if let Some(slot) = s.take() {
                sink(
                    slot.pending.conn_id,
                    Response::err(slot.pending.request.id, err.to_string()),
                );
                write_slot_mask(
                    &mut self.mask_t,
                    spec.n_layers,
                    spec.ffn_m,
                    si,
                    None,
                );
            }
        }
    }

    /// Drive the loop against a scheduler until it closes and drains:
    /// block for work only when idle, admit mid-flight otherwise.
    pub fn run(
        &mut self,
        sched: &Scheduler,
        sink: &mut dyn FnMut(u64, Response),
    ) {
        loop {
            let free = self.free_slots();
            if free > 0 {
                if self.active() == 0 {
                    // idle: block until work arrives (batch_window lets
                    // an initial burst form), or exit on close+empty
                    match sched.next_batch() {
                        Some(batch) => self.admit(batch, sink),
                        None => break,
                    }
                } else {
                    // mid-flight admission into free slots
                    let newly = sched.take(free);
                    if !newly.is_empty() {
                        self.admit(newly, sink);
                    }
                }
            }
            if self.active() == 0 {
                continue;
            }
            if let Err(e) = self.step(sink) {
                self.fail_all(&e, sink);
            }
        }
    }
}

fn finish_response(engine: &Engine, slot: &Slot) -> Response {
    let sess = &slot.sess;
    let mut resp = Response::ok(
        slot.pending.request.id,
        engine.decode_text(&sess.generated),
        sess.generated.len(),
        slot.prefill_ms,
        slot.decode_started.elapsed().as_secs_f64() * 1e3,
        sess.mask.density(),
    );
    resp.queue_ms = slot.queue_ms;
    resp.refreshes = sess.refreshes;
    resp.mask_updates = sess.mask_updates;
    resp.finish = sess
        .finished
        .unwrap_or(FinishReason::Length)
        .as_str()
        .to_string();
    resp
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unknown_strategy_is_an_error_not_iglass() {
        // the old serve path had a `_ =>` arm that silently served any
        // typo as i-GLASS; the resolver must reject instead
        for bad in ["bogus", "iglass", "I-GLASS", ""] {
            let err = resolve_strategy(bad, 0.5).unwrap_err();
            assert!(
                err.to_string().contains("unknown strategy"),
                "{bad}: {err}"
            );
        }
        for good in super::super::protocol::STRATEGIES {
            assert!(resolve_strategy(good, 0.5).is_ok(), "{good}");
        }
    }

    #[test]
    fn glass_variants_pick_matching_priors() {
        let (s, p) = resolve_strategy("a-glass", 0.25).unwrap();
        assert!(matches!(s, Strategy::Glass { lambda } if lambda == 0.25));
        assert_eq!(p, Some("a-glass"));
        let (_, p) = resolve_strategy("i-glass", 0.5).unwrap();
        assert_eq!(p, Some("i-glass"));
        let (s, p) = resolve_strategy("dense", 0.5).unwrap();
        assert!(matches!(s, Strategy::Dense));
        assert_eq!(p, None);
    }
}
