//! Zero-copy newline-frame scanning for the reactor's read path.
//!
//! Every v2 delta acknowledgement, cancel, resume, and stats command
//! arrives as one newline-terminated frame, so line splitting sits on
//! the per-token hot path. The old reactor copied each line out of
//! the connection's read buffer (`rbuf[..nl].to_vec()`) before
//! parsing; [`FrameScanner`] instead yields `&[u8]` slices that
//! borrow directly from the read buffer — no intermediate `String` or
//! `Vec` per frame — and tracks two cursors across calls:
//!
//! * `scanned` — how far the newline search has progressed, so bytes
//!   of a partial frame are never re-scanned when more data arrives;
//! * `consumed` — how many bytes belong to fully-yielded frames and
//!   can be drained from the FRONT of the buffer.
//!
//! Contract: between refills the buffer may only grow at the tail.
//! After draining exactly [`FrameScanner::consumed`] bytes from the
//! front, call [`FrameScanner::on_drain`] so the cursors shift with
//! the bytes. Equivalence with the previous allocating splitter is
//! pinned by a fuzz-style test below (random byte streams × random
//! chunk partitions).

/// Incremental zero-copy line scanner over an append-only buffer; see
/// the module docs for the cursor contract.
#[derive(Debug, Default, Clone)]
pub struct FrameScanner {
    scanned: usize,
    consumed: usize,
}

impl FrameScanner {
    /// A scanner with both cursors at the buffer start.
    pub fn new() -> FrameScanner {
        FrameScanner::default()
    }

    /// The next complete line in `buf` (newline stripped, borrowed
    /// from `buf`), or `None` once no full line remains — at which
    /// point the scan frontier has advanced to `buf.len()`, so the
    /// bytes of the trailing partial line are never re-scanned.
    pub fn next_line<'a>(&mut self, buf: &'a [u8]) -> Option<&'a [u8]> {
        match buf[self.scanned..].iter().position(|&b| b == b'\n') {
            Some(at) => {
                let nl = self.scanned + at;
                let line = &buf[self.consumed..nl];
                self.scanned = nl + 1;
                self.consumed = nl + 1;
                Some(line)
            }
            None => {
                self.scanned = buf.len();
                None
            }
        }
    }

    /// Bytes of fully-yielded lines, ready to be drained from the
    /// front of the buffer.
    pub fn consumed(&self) -> usize {
        self.consumed
    }

    /// Length of the trailing partial (not yet newline-terminated)
    /// frame, given the current buffer length — what the frame-size
    /// cap applies to.
    pub fn pending(&self, buf_len: usize) -> usize {
        buf_len.saturating_sub(self.consumed)
    }

    /// Account for `consumed()` bytes having been drained from the
    /// front of the buffer: both cursors shift down so they keep
    /// pointing at the same bytes.
    pub fn on_drain(&mut self) {
        self.scanned -= self.consumed;
        self.consumed = 0;
    }

    /// Forget everything (used when the connection abandons its read
    /// buffer after a protocol error).
    pub fn reset(&mut self) {
        self.scanned = 0;
        self.consumed = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The previous allocating splitter, kept verbatim as the
    /// reference model: scan for '\n' from a persistent frontier,
    /// copy each line out, drain consumed bytes from the front.
    struct AllocSplitter {
        rbuf: Vec<u8>,
        scanned: usize,
    }

    impl AllocSplitter {
        fn new() -> AllocSplitter {
            AllocSplitter {
                rbuf: Vec::new(),
                scanned: 0,
            }
        }

        fn feed(&mut self, chunk: &[u8]) -> Vec<Vec<u8>> {
            self.rbuf.extend_from_slice(chunk);
            let mut out = Vec::new();
            let mut consumed = 0usize;
            while let Some(at) =
                self.rbuf[self.scanned..].iter().position(|&b| b == b'\n')
            {
                let nl = self.scanned + at;
                out.push(self.rbuf[consumed..nl].to_vec());
                self.scanned = nl + 1;
                consumed = nl + 1;
            }
            if consumed > 0 {
                self.rbuf.drain(..consumed);
            }
            self.scanned = self.rbuf.len();
            out
        }
    }

    /// The new zero-copy path, driven exactly like the reactor drives
    /// it: take the buffer, yield borrowed lines, restore, drain.
    struct ZeroCopy {
        rbuf: Vec<u8>,
        scanner: FrameScanner,
    }

    impl ZeroCopy {
        fn new() -> ZeroCopy {
            ZeroCopy {
                rbuf: Vec::new(),
                scanner: FrameScanner::new(),
            }
        }

        fn feed(&mut self, chunk: &[u8]) -> Vec<Vec<u8>> {
            self.rbuf.extend_from_slice(chunk);
            let rbuf = std::mem::take(&mut self.rbuf);
            let mut out = Vec::new();
            while let Some(line) = self.scanner.next_line(&rbuf) {
                out.push(line.to_vec()); // copy only to compare
            }
            self.rbuf = rbuf;
            self.rbuf.drain(..self.scanner.consumed());
            self.scanner.on_drain();
            out
        }
    }

    /// xorshift64* — deterministic, dependency-free fuzz source.
    struct Prng(u64);

    impl Prng {
        fn next(&mut self) -> u64 {
            let mut x = self.0;
            x ^= x >> 12;
            x ^= x << 25;
            x ^= x >> 27;
            self.0 = x;
            x.wrapping_mul(0x2545_f491_4f6c_dd1d)
        }

        fn below(&mut self, n: usize) -> usize {
            (self.next() % n.max(1) as u64) as usize
        }
    }

    #[test]
    fn yields_lines_and_tracks_partial_frames() {
        let mut s = FrameScanner::new();
        let mut buf: Vec<u8> = b"alpha\nbe".to_vec();
        assert_eq!(s.next_line(&buf), Some(&b"alpha"[..]));
        assert_eq!(s.next_line(&buf), None);
        assert_eq!(s.consumed(), 6);
        assert_eq!(s.pending(buf.len()), 2);
        buf.drain(..s.consumed());
        s.on_drain();
        buf.extend_from_slice(b"ta\n\ngamma");
        assert_eq!(s.next_line(&buf), Some(&b"beta"[..]));
        assert_eq!(s.next_line(&buf), Some(&b""[..]));
        assert_eq!(s.next_line(&buf), None);
        assert_eq!(s.pending(buf.len()), 5);
        buf.drain(..s.consumed());
        s.on_drain();
        assert_eq!(buf, b"gamma");
    }

    #[test]
    fn never_rescans_partial_bytes() {
        // the frontier must sit at buf.len() after a miss, so feeding
        // one byte at a time costs O(1) per byte, not O(len²)
        let mut s = FrameScanner::new();
        let mut buf = Vec::new();
        for _ in 0..100 {
            buf.push(b'x');
            assert_eq!(s.next_line(&buf), None);
        }
        buf.push(b'\n');
        assert_eq!(s.next_line(&buf), Some(&buf.clone()[..100]));
    }

    #[test]
    fn fuzz_equivalence_with_allocating_splitter() {
        let mut rng = Prng(0x9e37_79b9_7f4a_7c15);
        for round in 0..200 {
            // random stream: frames of random length (some empty, some
            // long, occasional embedded '\r' and UTF-8 bytes), with a
            // random trailing partial frame
            let mut stream = Vec::new();
            for _ in 0..rng.below(12) {
                let len = rng.below(40);
                for _ in 0..len {
                    let b = match rng.below(8) {
                        0 => b'\r',
                        1 => 0xC3, // multi-byte UTF-8 lead
                        _ => b'a' + (rng.below(26) as u8),
                    };
                    stream.push(b);
                }
                stream.push(b'\n');
            }
            for _ in 0..rng.below(10) {
                stream.push(b'z');
            }
            // random partition into feed() chunks
            let mut old = AllocSplitter::new();
            let mut new = ZeroCopy::new();
            let mut at = 0usize;
            while at < stream.len() {
                let take = (1 + rng.below(16)).min(stream.len() - at);
                let chunk = &stream[at..at + take];
                assert_eq!(
                    old.feed(chunk),
                    new.feed(chunk),
                    "round {round}: divergence at offset {at}"
                );
                assert_eq!(old.rbuf, new.rbuf, "round {round}: leftovers differ");
                assert_eq!(
                    new.scanner.pending(new.rbuf.len()),
                    new.rbuf.len(),
                    "after a full drain the whole leftover is one partial frame"
                );
                at += take;
            }
            // an empty refill yields nothing and disturbs nothing
            assert_eq!(old.feed(&[]), new.feed(&[]), "round {round}");
            assert_eq!(old.rbuf, new.rbuf, "round {round}");
        }
    }

    #[test]
    fn reset_forgets_everything() {
        let mut s = FrameScanner::new();
        let buf = b"abc\ndef".to_vec();
        assert!(s.next_line(&buf).is_some());
        s.reset();
        assert_eq!(s.consumed(), 0);
        let fresh = b"xyz\n".to_vec();
        assert_eq!(s.next_line(&fresh), Some(&b"xyz"[..]));
    }
}
