//! Wire protocol for the serving layer: framed **protocol v2**
//! (multiplexed streaming sessions) plus the legacy **v1** one-shot
//! JSON-line protocol, auto-detected per connection.
//!
//! # Framing and version negotiation
//!
//! Both protocols are newline-delimited JSON objects ("frames"), one
//! per line, in each direction over TCP. The FIRST parsed line of a
//! connection picks its protocol: a frame carrying `"v": 2` locks the
//! connection to v2; any other object locks it to v1 and is served
//! bit-identically to the pre-v2 server — the compatibility shim
//! suppresses non-terminal events and serializes the terminal event in
//! the v1 response shape ([`Event::into_response`]), so a v1 client
//! cannot observe the reactor rewrite. (One deliberate exception: a
//! request arriving during graceful shutdown gets an explicit
//! retryable error line where the old server went silent.) Any single frame larger than
//! the server's `max_frame_bytes` (newline seen or not) is a protocol
//! error that closes the connection — per-connection read buffering is
//! bounded.
//!
//! # v1 (legacy, one frame in → one frame out)
//!
//!   request:  {"id": 7, "prompt": "...", "strategy": "i-glass",
//!              "lambda": 0.5, "density": 0.5, "max_tokens": 64,
//!              "refresh_every": 8, "cache": "on"}
//!   response: {"id": 7, "text": "...", "tokens": 42,
//!              "prompt_tokens": 25, "cached_prompt_tokens": 20,
//!              "cache_hits": 1, "cache_evictions": 0,
//!              "prefill_ms": 1.2, "decode_ms": 30.5, "queue_ms": 0.3,
//!              "density": 0.5, "refreshes": 5, "mask_updates": 2,
//!              "finish": "length"}
//!   error:    {"id": 7, "error": "..."}
//!   command:  {"cmd": "stats", "id": 3} → see **stats** below
//!
//! # v2 client → server frames
//!
//!   {"v":2,"cmd":"generate","id":7,"prompt":"...", ...}   start session 7
//!   {"v":2,"cmd":"resume","id":7,"prompt":"...",
//!    "received":12, ...}                                  resume session 7
//!   {"v":2,"cmd":"cancel","id":7}                         cancel session 7
//!   {"v":2,"cmd":"set","id":7,"refresh_every":4}          live knob adjust
//!   {"v":2,"cmd":"stats","id":3}                          server counters
//!
//! `generate` takes every v1 request field (strategy, lambda, density,
//! max_tokens, refresh_every, cache), validated identically at parse
//! time. The session `id` is **connection-scoped** and must be ≥ 1
//! (id 0 is reserved as the correlation id of connection-level
//! protocol errors): starting a session whose id is still live on the
//! same connection is answered with an `error` frame **on id 0**
//! naming the duplicate — never on the session's own id, whose live
//! stream is unaffected. An id may be reused after its terminal
//! frame; consume the reply to a `cancel`/`set` before reusing its
//! id, since that reply is correlated on the target id. `cancel` stops a live session —
//! its decode slot is freed within one decode step and nothing is
//! re-queued; the session's terminal frame is a `done` with
//! `finish: "cancel"` carrying the tokens decoded so far (a queued,
//! not-yet-admitted session cancels to a zero-token `done`). `set`
//! adjusts `refresh_every` for a live session mid-stream (takes effect
//! from the next decoded token). `cancel`/`set` for an id that is not
//! live on this connection is a **no-op**: the server answers with an
//! `error` frame and the connection stays up. A `cancel`/`set` that
//! loses the race with its session's natural completion is silently
//! dropped — the session's real terminal frame is already on its way,
//! and a session receives exactly ONE terminal frame, always.
//!
//! # v2 server → client event frames
//!
//!   {"v":2,"ev":"accepted","id":7,"queue_pos":0}
//!   {"v":2,"ev":"queue","id":7,"position":3}
//!   {"v":2,"ev":"delta","id":7,"index":0,"text":"the "}
//!   {"v":2,"ev":"refresh","id":7,"refreshes":1,"mask_updates":1,
//!    "changed":true}
//!   {"v":2,"ev":"done","id":7, ...all v1 response fields...}
//!   {"v":2,"ev":"error","id":7,"error":"...","retryable":false}
//!
//! # Event ordering guarantees
//!
//! Per session id: `accepted` first (with the position in the target
//! shard's queue at submission), then zero or more `delta` / `refresh`
//! frames, then exactly ONE terminal frame (`done` or `error`).
//!
//! While a session waits for admission the server pushes
//! server-initiated `queue` frames: one whenever the session's queue
//! position CHANGES (0 = next to be admitted), never twice for the
//! same position, and always strictly between `accepted` and the
//! session's first `delta` (a session admitted straight into a slot
//! emits none). `queue` frames are non-terminal progress telemetry —
//! blocking collectors ([`Event::into_response`], the v1 shim) ignore
//! them bit-compatibly, so a v2 client that predates them keeps
//! working unchanged.
//! `delta.index` is contiguous from 0; every delta carries a valid
//! UTF-8 chunk and the concatenation of all delta texts is
//! byte-identical to the `done` frame's `text` — which is itself
//! bit-identical to the v1 blocking response for the same request
//! (incomplete multi-byte sequences are held back until completed or
//! flushed on the terminal frame). A `refresh` frame reports one GLASS
//! mask re-aggregation; `changed` is whether the kept set moved, and
//! the refreshed mask applies from the next decoded token on. Frames
//! of DIFFERENT sessions interleave arbitrarily — that is the point of
//! multiplexing — but each session's frames are totally ordered as
//! above. `finish` in a `done` frame is "length" (max_tokens / KV
//! window), "stop" (special token), or "cancel" (client-initiated).
//!
//! On graceful shutdown, in-flight sessions drain to their natural
//! `done` while queued-but-unadmitted sessions receive an `error`
//! frame with `retryable: true` — a client may resubmit them verbatim
//! to another server.
//!
//! # resume
//!
//! A client whose connection died mid-stream (or whose session was
//! failed with `retryable: true`) reconnects and replays the session:
//!
//!   {"v":2,"cmd":"resume","id":7,"prompt":"...","received":12,
//!    ...every generate field...}
//!
//! `resume` carries the ORIGINAL request verbatim (same prompt and
//! knobs, validated identically to `generate`) plus `received` — the
//! number of `delta` frames the client has already consumed. The
//! server re-admits the session like a generate: the prompt re-enters
//! through the shared-prefix cache, so a prefix published by the
//! original run (or restored from a `--cache-dir` snapshot) is spliced
//! instead of re-prefilled, and decode re-runs deterministically from
//! the prompt. Deltas the client already holds are regenerated but
//! **suppressed**, not re-sent.
//!
//! Ordering guarantees for a resumed session: `accepted` first, then
//! deltas with `index` contiguous from `received` (NOT from 0 — the
//! one deliberate exception to the generate ordering rule), then
//! exactly one terminal frame. The concatenation of the original
//! stream's deltas `[0, received)` with the resumed stream's deltas
//! `[received, ...)` is byte-identical to an uninterrupted stream's
//! concatenation — and therefore to the `done` frame's `text`, which
//! reports the FULL generation (all tokens, not just the resumed
//! tail). A `received` beyond the number of deltas the request can
//! produce simply yields a resumed stream with no deltas before its
//! terminal. Cancel/set address a resumed session exactly like a
//! generated one.
//!
//! # stats
//!
//! The `stats` command is answered with the same JSON line in BOTH
//! protocols (an object with `id` / `stats` / `shards` keys and no
//! `ev` key): server-level aggregate cache counters (hits, misses,
//! inserts, evictions, bytes resident, entries — summed across every
//! shard's cache) plus one [`ShardSnapshot`] per serving shard (queue
//! depth, decode / prefill slot occupancy, batch width). The per-shard
//! gauges are published by each batcher as ONE atomic word, so a stats
//! call during heavy admission can never observe `slots_active +
//! slots_prefilling` above the batch width.
//!
//! # Field ranges
//!
//! Validated at parse time and rejected with an immediate protocol
//! error (never surfaced as a deep engine failure): `density` must lie
//! in (0, 1], `lambda` in [0, 1], `max_tokens` must be ≥ 1, and
//! `cache` must be one of on|off|readonly.
//!
//! **Prompt length.** Prompts are NOT bounded by the prefill frame:
//! the batcher streams long prompts through chunked prefill (see
//! [`super::batcher`]), so any prompt whose encoded length plus
//! `max_tokens` fits the serving capacity of `max_seq + 1` (the
//! `max_seq`-position KV window plus one final token that needs no KV
//! write) is served in full. Beyond that the request is rejected with
//! an explicit "prompt too long" error — prompt tokens are never
//! silently dropped. `prompt_tokens` in the response reports how many
//! prompt tokens (incl. BOS) were actually prefilled.
//!
//! # Wire-key registry
//!
//! Every key this module's serializers write — and every key the
//! client reads — is registered here. glass-lint's protocol-key-drift
//! rule fails CI when the serializers, [`super::client`], and this
//! list disagree, so a new field cannot ship undocumented (or
//! misspelled on one side of the wire).
//!
//! * Envelope and commands: `v`, `cmd`, `id`, `ev`.
//! * Request knobs: `prompt`, `strategy`, `lambda`, `density`,
//!   `max_tokens`, `refresh_every`, `cache`, `received`, `tier`.
//! * Event and response fields: `index`, `text`, `finish`, `error`,
//!   `retryable`, `queue_pos`, `position`, `changed`, `tokens`,
//!   `prompt_tokens`, `cached_prompt_tokens`, `refreshes`,
//!   `mask_updates`, `prefill_ms`, `decode_ms`, `queue_ms`,
//!   `degraded`, `effective_density`.
//! * Stats reply: `stats`, `shards`, `cache_hits`, `cache_misses`,
//!   `cache_inserts`, `cache_evictions`, `cache_bytes_resident`,
//!   `cache_entries`, `cache_warm_start_hits`, `shard`,
//!   `queue_depth`, `slots_active`, `slots_prefilling`,
//!   `batch_width`, `governor_level`, `degraded_requests`,
//!   `stolen_requests`.
//!
//! # SLO tiers and load governance
//!
//! `tier` classifies a request's latency expectation for the overload
//! governor (see the "Load governance" section of [`super`]): one of
//! `interactive` | `standard` | `batch`, default `standard`, validated
//! at parse time like every other knob. Under pressure the governor
//! may serve a request sparser than asked; the `done` frame then
//! carries `degraded: true` and `effective_density` — the density the
//! request was actually served at (equal to the requested `density`
//! when `degraded` is false). Both fields are always present on
//! success frames; clients reading pre-governor servers default them
//! to `false` / the reported `density`. The `stats` reply grows three
//! per-shard counters: `governor_level` (the shard's current
//! degradation level, 0 = none), `degraded_requests`, and
//! `stolen_requests` (admissions re-routed off a saturated shard).

use anyhow::{bail, Result};

use crate::engine::prefix_cache::{CacheMode, CacheStatsSnapshot};
use crate::util::json::Json;

/// The framed multiplexed protocol version this server speaks.
pub const PROTOCOL_V2: usize = 2;

/// Strategy names the serving layer accepts.
pub const STRATEGIES: &[&str] =
    &["dense", "griffin", "global", "a-glass", "i-glass"];

/// A request's SLO tier: how latency-sensitive the caller is, and
/// therefore how early the overload governor may degrade it (batch
/// first, interactive last). Carried on the wire as the request knob
/// `tier`; unknown names are rejected at parse time.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default, Hash)]
pub enum Tier {
    /// A human is waiting on every token: degraded last, queued first.
    Interactive,
    /// The default tier for unclassified traffic.
    #[default]
    Standard,
    /// Latency-tolerant bulk work: degraded first, queued last.
    Batch,
}

impl Tier {
    /// Parse a wire tier name (`interactive` | `standard` | `batch`).
    pub fn parse(s: &str) -> Result<Tier> {
        Ok(match s {
            "interactive" => Tier::Interactive,
            "standard" => Tier::Standard,
            "batch" => Tier::Batch,
            other => bail!(
                "unknown tier '{other}' (expected interactive|standard|batch)"
            ),
        })
    }

    /// The wire name of this tier.
    pub fn as_str(&self) -> &'static str {
        match self {
            Tier::Interactive => "interactive",
            Tier::Standard => "standard",
            Tier::Batch => "batch",
        }
    }

    /// Scheduling rank: lower drains first (interactive < standard <
    /// batch). The scheduler uses this with age-based anti-starvation;
    /// see [`super::scheduler`].
    pub fn rank(&self) -> u8 {
        match self {
            Tier::Interactive => 0,
            Tier::Standard => 1,
            Tier::Batch => 2,
        }
    }
}

/// One generation request, as carried by a v1 request line or a v2
/// `generate`/`resume` frame.
#[derive(Debug, Clone, PartialEq)]
pub struct Request {
    /// Correlation id (v1) / session id (v2, must be nonzero).
    pub id: u64,
    /// The prompt text to prefill.
    pub prompt: String,
    /// One of [`STRATEGIES`].
    pub strategy: String,
    /// Global/local fusion weight λ ∈ [0, 1].
    pub lambda: f64,
    /// Kept-neuron fraction ∈ (0, 1].
    pub density: f64,
    /// Decode budget: generation stops after this many tokens.
    pub max_tokens: usize,
    /// Refresh the GLASS mask every N decoded tokens (0 = never).
    pub refresh_every: usize,
    /// Shared-prefix cache behavior for this request.
    pub cache: CacheMode,
    /// SLO tier for the overload governor (default [`Tier::Standard`]).
    pub tier: Tier,
}

/// One parsed v1 client line: a generation request or a server command.
#[derive(Debug, Clone, PartialEq)]
pub enum ClientLine {
    Request(Request),
    /// `{"cmd": "stats"}` — report server-level cache counters.
    Stats { id: u64 },
}

/// One parsed v2 client frame.
#[derive(Debug, Clone, PartialEq)]
pub enum V2Frame {
    /// `{"v":2,"cmd":"generate",...}` — start a streaming session.
    Generate(Request),
    /// `{"v":2,"cmd":"resume","received":K,...}` — replay a dropped
    /// session: the original request plus the count of delta frames
    /// already consumed (regenerated deltas below `received` are
    /// suppressed server-side).
    Resume { req: Request, received: u64 },
    /// `{"v":2,"cmd":"cancel","id":N}` — stop a live session.
    Cancel { id: u64 },
    /// `{"v":2,"cmd":"set","id":N,"refresh_every":R}` — live knob.
    Set { id: u64, refresh_every: usize },
    /// `{"v":2,"cmd":"stats","id":N}` — server counters.
    Stats { id: u64 },
}

/// Parse one v1 client line, dispatching on the optional `cmd` key. The
/// document is parsed ONCE and shared with [`Request::from_json`] —
/// this sits on the per-line hot path of every connection.
pub fn parse_client_line(line: &str) -> Result<ClientLine> {
    let j = Json::parse(line)?;
    client_line_from_json(&j)
}

/// [`parse_client_line`] over an already-parsed document (the reactor
/// parses each line once to detect the protocol version).
pub fn client_line_from_json(j: &Json) -> Result<ClientLine> {
    let Some(cmd) = j.get("cmd") else {
        return Request::from_json(j).map(ClientLine::Request);
    };
    let id = opt_id(j)?;
    match cmd.as_str()? {
        "stats" => Ok(ClientLine::Stats { id }),
        other => bail!("unknown command '{other}'"),
    }
}

/// The `"v"` key of a frame: `None` = unversioned (v1), `Some(n)`
/// otherwise. The reactor locks a connection's protocol from its first
/// parsed line.
pub fn frame_version(j: &Json) -> Result<Option<usize>> {
    match j.get("v") {
        Some(v) => Ok(Some(v.as_usize()?)),
        None => Ok(None),
    }
}

fn opt_id(j: &Json) -> Result<u64> {
    Ok(match j.get("id") {
        Some(v) => v.as_usize()? as u64,
        None => 0,
    })
}

/// Parse one v2 frame from an already-parsed document. The `"v"` key
/// must be present and equal to 2 (the reactor checks this before
/// locking the connection to v2, so a `"v":3` frame is an explicit
/// "unsupported protocol version" error, not a silent v1 fallback).
pub fn v2_frame_from_json(j: &Json) -> Result<V2Frame> {
    let v = j.req("v")?.as_usize()?;
    if v != PROTOCOL_V2 {
        bail!("unsupported protocol version {v} (this server speaks v1 and v2)");
    }
    let cmd = j.req("cmd")?.as_str()?;
    match cmd {
        "generate" => Request::from_json(j).map(V2Frame::Generate),
        "resume" => Ok(V2Frame::Resume {
            req: Request::from_json(j)?,
            received: j.req("received")?.as_usize()? as u64,
        }),
        "cancel" => Ok(V2Frame::Cancel { id: j.req("id")?.as_usize()? as u64 }),
        "set" => Ok(V2Frame::Set {
            id: j.req("id")?.as_usize()? as u64,
            refresh_every: j.req("refresh_every")?.as_usize()?,
        }),
        "stats" => Ok(V2Frame::Stats { id: opt_id(j)? }),
        other => bail!("unknown v2 command '{other}'"),
    }
}

// ----------------------------------------------------------- events

/// One server→client event. In v2 every event is serialized as its own
/// frame ([`Event::to_frame`]); the v1 compatibility shim drops
/// non-terminal events and serializes the terminal one as the classic
/// response line ([`Event::into_response`]).
#[derive(Debug, Clone, PartialEq)]
pub enum Event {
    /// Session admitted to a shard's queue (position at submission).
    Accepted { id: u64, queue_pos: u64 },
    /// Server-initiated queue progress: the session's position in its
    /// shard's admission queue changed (0 = next to be admitted).
    /// Emitted only on change, only between `accepted` and the first
    /// `delta`; non-terminal and ignored bit-compatibly by blocking
    /// collectors.
    Queue { id: u64, position: u64 },
    /// Incremental generation text. `index` is contiguous from 0; the
    /// concatenation of all delta texts equals the final `done` text.
    Delta { id: u64, index: u64, text: String },
    /// One GLASS mask re-aggregation ran for this session.
    Refresh {
        id: u64,
        refreshes: u64,
        mask_updates: u64,
        changed: bool,
    },
    /// Terminal: the completed response (finish length|stop|cancel).
    Done(Response),
    /// Terminal: the session failed. `retryable` marks errors where
    /// resubmitting the identical request may succeed (e.g. server
    /// shutdown before admission), vs. permanent rejections
    /// (validation, prompt too long, cancel of an unknown id).
    Error {
        id: u64,
        error: String,
        retryable: bool,
    },
}

impl Event {
    /// The session id this event belongs to.
    pub fn id(&self) -> u64 {
        match self {
            Event::Accepted { id, .. }
            | Event::Queue { id, .. }
            | Event::Delta { id, .. }
            | Event::Refresh { id, .. }
            | Event::Error { id, .. } => *id,
            Event::Done(r) => r.id,
        }
    }

    /// Terminal events end a session (exactly one per session).
    pub fn is_terminal(&self) -> bool {
        matches!(self, Event::Done(_) | Event::Error { .. })
    }

    /// The v1 compatibility shim: terminal events become the classic
    /// one-line response, everything else is suppressed. This is what
    /// makes a v1 client's byte stream identical to the pre-v2 server.
    pub fn into_response(self) -> Option<Response> {
        match self {
            Event::Done(r) => Some(r),
            Event::Error { id, error, .. } => Some(Response::err(id, error)),
            _ => None,
        }
    }

    /// Serialize as a v2 event frame (one JSON line).
    pub fn to_frame(&self) -> String {
        let mut o = Json::obj();
        o.set("v", Json::Num(PROTOCOL_V2 as f64));
        match self {
            Event::Accepted { id, queue_pos } => {
                o.set("ev", Json::Str("accepted".into()))
                    .set("id", Json::Num(*id as f64))
                    .set("queue_pos", Json::Num(*queue_pos as f64));
            }
            Event::Queue { id, position } => {
                o.set("ev", Json::Str("queue".into()))
                    .set("id", Json::Num(*id as f64))
                    .set("position", Json::Num(*position as f64));
            }
            Event::Delta { id, index, text } => {
                o.set("ev", Json::Str("delta".into()))
                    .set("id", Json::Num(*id as f64))
                    .set("index", Json::Num(*index as f64))
                    .set("text", Json::Str(text.clone()));
            }
            Event::Refresh {
                id,
                refreshes,
                mask_updates,
                changed,
            } => {
                o.set("ev", Json::Str("refresh".into()))
                    .set("id", Json::Num(*id as f64))
                    .set("refreshes", Json::Num(*refreshes as f64))
                    .set("mask_updates", Json::Num(*mask_updates as f64))
                    .set("changed", Json::Bool(*changed));
            }
            Event::Done(resp) => {
                o.set("ev", Json::Str("done".into()));
                if let Json::Obj(fields) = resp.to_json() {
                    for (k, v) in fields {
                        o.set(&k, v);
                    }
                }
            }
            Event::Error {
                id,
                error,
                retryable,
            } => {
                o.set("ev", Json::Str("error".into()))
                    .set("id", Json::Num(*id as f64))
                    .set("error", Json::Str(error.clone()))
                    .set("retryable", Json::Bool(*retryable));
            }
        }
        o.to_string()
    }

    /// Parse a v2 event frame (client side).
    pub fn parse_frame(j: &Json) -> Result<Event> {
        let ev = j.req("ev")?.as_str()?;
        let id = opt_id(j)?;
        Ok(match ev {
            "accepted" => Event::Accepted {
                id,
                queue_pos: j.req("queue_pos")?.as_usize()? as u64,
            },
            "queue" => Event::Queue {
                id,
                position: j.req("position")?.as_usize()? as u64,
            },
            "delta" => Event::Delta {
                id,
                index: j.req("index")?.as_usize()? as u64,
                text: j.req("text")?.as_str()?.to_string(),
            },
            "refresh" => Event::Refresh {
                id,
                refreshes: j.req("refreshes")?.as_usize()? as u64,
                mask_updates: j.req("mask_updates")?.as_usize()? as u64,
                changed: j.req("changed")?.as_bool()?,
            },
            "done" => Event::Done(Response::from_json(j)?),
            "error" => Event::Error {
                id,
                error: j.req("error")?.as_str()?.to_string(),
                retryable: match j.get("retryable") {
                    Some(v) => v.as_bool()?,
                    None => false,
                },
            },
            other => bail!("unknown event '{other}'"),
        })
    }
}

/// One serving shard's live counters, as reported by the `stats`
/// command: scheduler queue depth plus decode / prefill slot occupancy
/// (gauges the shard's batcher publishes as one atomic word every loop
/// iteration, so the pair is always mutually consistent).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct ShardSnapshot {
    /// Shard index (also the routing target of `route_shard`).
    pub shard: u64,
    /// Requests waiting in this shard's scheduler queue.
    pub queue_depth: u64,
    /// Slots currently decoding.
    pub slots_active: u64,
    /// Slots currently streaming a chunked prefill.
    pub slots_prefilling: u64,
    /// Slot capacity (occupancy denominator).
    pub batch_width: u64,
    /// The overload governor's current degradation level for this
    /// shard (0 = serving everything at requested density).
    pub governor_level: u64,
    /// Admissions this shard served sparser than requested.
    pub degraded_requests: u64,
    /// Admissions re-routed to this shard off a saturated home shard.
    pub stolen_requests: u64,
}

/// Serialize the `stats` command response line: aggregate cache
/// counters plus one entry per serving shard.
pub fn stats_to_line(
    id: u64,
    s: &CacheStatsSnapshot,
    shards: &[ShardSnapshot],
) -> String {
    let mut inner = Json::obj();
    inner
        .set("cache_hits", Json::Num(s.hits as f64))
        .set("cache_misses", Json::Num(s.misses as f64))
        .set("cache_inserts", Json::Num(s.inserts as f64))
        .set("cache_evictions", Json::Num(s.evictions as f64))
        .set("cache_bytes_resident", Json::Num(s.bytes_resident as f64))
        .set("cache_entries", Json::Num(s.entries as f64))
        .set(
            "cache_warm_start_hits",
            Json::Num(s.warm_start_hits as f64),
        );
    let per_shard: Vec<Json> = shards
        .iter()
        .map(|sh| {
            let mut o = Json::obj();
            o.set("shard", Json::Num(sh.shard as f64))
                .set("queue_depth", Json::Num(sh.queue_depth as f64))
                .set("slots_active", Json::Num(sh.slots_active as f64))
                .set(
                    "slots_prefilling",
                    Json::Num(sh.slots_prefilling as f64),
                )
                .set("batch_width", Json::Num(sh.batch_width as f64))
                .set(
                    "governor_level",
                    Json::Num(sh.governor_level as f64),
                )
                .set(
                    "degraded_requests",
                    Json::Num(sh.degraded_requests as f64),
                )
                .set(
                    "stolen_requests",
                    Json::Num(sh.stolen_requests as f64),
                );
            o
        })
        .collect();
    let mut o = Json::obj();
    o.set("id", Json::Num(id as f64))
        .set("stats", inner)
        .set("shards", Json::Arr(per_shard));
    o.to_string()
}

/// Parse a `stats` response line back into the aggregate snapshot and
/// the per-shard counters (client side). A line without a `shards` key
/// (pre-sharding server) parses to an empty shard list.
pub fn parse_stats_line(
    line: &str,
) -> Result<(u64, CacheStatsSnapshot, Vec<ShardSnapshot>)> {
    let j = Json::parse(line)?;
    let id = j.req("id")?.as_usize()? as u64;
    let s = j.req("stats")?;
    let get = |doc: &Json, k: &str| -> Result<u64> {
        Ok(match doc.get(k) {
            Some(v) => v.as_usize()? as u64,
            None => 0,
        })
    };
    let snap = CacheStatsSnapshot {
        hits: get(s, "cache_hits")?,
        misses: get(s, "cache_misses")?,
        inserts: get(s, "cache_inserts")?,
        evictions: get(s, "cache_evictions")?,
        bytes_resident: get(s, "cache_bytes_resident")?,
        entries: get(s, "cache_entries")?,
        warm_start_hits: get(s, "cache_warm_start_hits")?,
    };
    let shards = match j.get("shards") {
        Some(arr) => arr
            .as_arr()?
            .iter()
            .map(|sh| {
                Ok(ShardSnapshot {
                    shard: get(sh, "shard")?,
                    queue_depth: get(sh, "queue_depth")?,
                    slots_active: get(sh, "slots_active")?,
                    slots_prefilling: get(sh, "slots_prefilling")?,
                    batch_width: get(sh, "batch_width")?,
                    governor_level: get(sh, "governor_level")?,
                    degraded_requests: get(sh, "degraded_requests")?,
                    stolen_requests: get(sh, "stolen_requests")?,
                })
            })
            .collect::<Result<Vec<ShardSnapshot>>>()?,
        None => Vec::new(),
    };
    Ok((id, snap, shards))
}

impl Request {
    /// Parse one raw v1 request line.
    pub fn parse(line: &str) -> Result<Request> {
        Request::from_json(&Json::parse(line)?)
    }

    /// Build from an already-parsed document (shared with
    /// [`client_line_from_json`] and [`v2_frame_from_json`] so request
    /// lines are parsed once).
    pub fn from_json(j: &Json) -> Result<Request> {
        let get_f = |k: &str, d: f64| -> Result<f64> {
            match j.get(k) {
                Some(v) => v.as_f64(),
                None => Ok(d),
            }
        };
        let get_u = |k: &str, d: usize| -> Result<usize> {
            match j.get(k) {
                Some(v) => v.as_usize(),
                None => Ok(d),
            }
        };
        let strategy = match j.get("strategy") {
            Some(v) => v.as_str()?.to_string(),
            None => "i-glass".to_string(),
        };
        if !STRATEGIES.contains(&strategy.as_str()) {
            bail!("unknown strategy '{strategy}'");
        }
        // range-validate numeric knobs here so a bad request dies as an
        // immediate protocol error, not a deep engine failure mid-batch
        let lambda = get_f("lambda", 0.5)?;
        if !(0.0..=1.0).contains(&lambda) {
            bail!("lambda {lambda} outside [0, 1]");
        }
        let density = get_f("density", 0.5)?;
        if !(density > 0.0 && density <= 1.0) {
            bail!("density {density} outside (0, 1]");
        }
        let max_tokens = get_u("max_tokens", 64)?;
        if max_tokens == 0 {
            bail!("max_tokens must be >= 1");
        }
        let cache = match j.get("cache") {
            Some(v) => CacheMode::parse(v.as_str()?)?,
            None => CacheMode::On,
        };
        let tier = match j.get("tier") {
            Some(v) => Tier::parse(v.as_str()?)?,
            None => Tier::Standard,
        };
        Ok(Request {
            id: j.req("id")?.as_usize()? as u64,
            prompt: j.req("prompt")?.as_str()?.to_string(),
            strategy,
            lambda,
            density,
            max_tokens,
            refresh_every: get_u("refresh_every", 0)?,
            cache,
            tier,
        })
    }

    fn fields_into(&self, o: &mut Json) {
        o.set("id", Json::Num(self.id as f64))
            .set("prompt", Json::Str(self.prompt.clone()))
            .set("strategy", Json::Str(self.strategy.clone()))
            .set("lambda", Json::Num(self.lambda))
            .set("density", Json::Num(self.density))
            .set("max_tokens", Json::Num(self.max_tokens as f64))
            .set("refresh_every", Json::Num(self.refresh_every as f64))
            .set("cache", Json::Str(self.cache.as_str().to_string()))
            .set("tier", Json::Str(self.tier.as_str().to_string()));
    }

    /// v1 request line.
    pub fn to_line(&self) -> String {
        let mut o = Json::obj();
        self.fields_into(&mut o);
        o.to_string()
    }

    /// v2 `generate` frame for the same request.
    pub fn to_v2_frame(&self) -> String {
        let mut o = Json::obj();
        o.set("v", Json::Num(PROTOCOL_V2 as f64))
            .set("cmd", Json::Str("generate".into()));
        self.fields_into(&mut o);
        o.to_string()
    }

    /// v2 `resume` frame: the same request replayed verbatim plus the
    /// count of delta frames the client already consumed.
    pub fn to_v2_resume_frame(&self, received: u64) -> String {
        let mut o = Json::obj();
        o.set("v", Json::Num(PROTOCOL_V2 as f64))
            .set("cmd", Json::Str("resume".into()))
            .set("received", Json::Num(received as f64));
        self.fields_into(&mut o);
        o.to_string()
    }
}

/// v2 `cancel` frame for session `id`.
pub fn cancel_frame(id: u64) -> String {
    let mut o = Json::obj();
    o.set("v", Json::Num(PROTOCOL_V2 as f64))
        .set("cmd", Json::Str("cancel".into()))
        .set("id", Json::Num(id as f64));
    o.to_string()
}

/// v2 `set` frame adjusting `refresh_every` for live session `id`.
pub fn set_frame(id: u64, refresh_every: usize) -> String {
    let mut o = Json::obj();
    o.set("v", Json::Num(PROTOCOL_V2 as f64))
        .set("cmd", Json::Str("set".into()))
        .set("id", Json::Num(id as f64))
        .set("refresh_every", Json::Num(refresh_every as f64));
    o.to_string()
}

/// v2 `stats` frame.
pub fn stats_frame(id: u64) -> String {
    let mut o = Json::obj();
    o.set("v", Json::Num(PROTOCOL_V2 as f64))
        .set("cmd", Json::Str("stats".into()))
        .set("id", Json::Num(id as f64));
    o.to_string()
}

#[derive(Debug, Clone, PartialEq)]
/// One completed request: the v1 response line / the payload of a v2
/// `done` frame.
pub struct Response {
    /// Echo of the request's correlation/session id.
    pub id: u64,
    /// The full generated text (the whole generation, even when the
    /// session was resumed).
    pub text: String,
    /// Generated token count.
    pub tokens: usize,
    /// Prompt tokens actually prefilled (incl. BOS). Lets a client
    /// distinguish a full-prompt response from a truncated one — the
    /// engine never truncates silently, and this field proves it.
    pub prompt_tokens: usize,
    /// Prompt tokens spliced from the shared-prefix cache instead of
    /// being recomputed (0 = cold prefill or cache off).
    pub cached_prompt_tokens: usize,
    /// Cache entries this request used (0 or 1 today).
    pub cache_hits: usize,
    /// Entries this request's own cache inserts evicted.
    pub cache_evictions: usize,
    /// Wall-clock prefill time (cache splicing included).
    pub prefill_ms: f64,
    /// Wall-clock decode time.
    pub decode_ms: f64,
    /// Time spent queued before admission into a batch slot.
    pub queue_ms: f64,
    /// Effective kept-neuron fraction served.
    pub density: f64,
    /// Whether the overload governor served this request sparser (or
    /// with a longer refresh interval) than requested.
    pub degraded: bool,
    /// The density the request was actually served at — equal to the
    /// requested density unless `degraded` is true.
    pub effective_density: f64,
    /// Mask refreshes applied / refreshes that changed the kept set.
    pub refreshes: usize,
    /// Refreshes whose recomputed mask changed the kept set.
    pub mask_updates: usize,
    /// "length" | "stop" | "cancel" ("" on errors).
    pub finish: String,
    /// Failure detail; `None` on success.
    pub error: Option<String>,
}

impl Response {
    /// A successful response (finish reason "length"); the optional
    /// stats fields start zeroed.
    pub fn ok(
        id: u64,
        text: String,
        tokens: usize,
        prefill_ms: f64,
        decode_ms: f64,
        density: f64,
    ) -> Response {
        Response {
            id,
            text,
            tokens,
            prompt_tokens: 0,
            cached_prompt_tokens: 0,
            cache_hits: 0,
            cache_evictions: 0,
            prefill_ms,
            decode_ms,
            queue_ms: 0.0,
            density,
            degraded: false,
            effective_density: density,
            refreshes: 0,
            mask_updates: 0,
            finish: "length".to_string(),
            error: None,
        }
    }

    /// An error response carrying `msg`; every stat is zeroed.
    pub fn err(id: u64, msg: String) -> Response {
        Response {
            id,
            text: String::new(),
            tokens: 0,
            prompt_tokens: 0,
            cached_prompt_tokens: 0,
            cache_hits: 0,
            cache_evictions: 0,
            prefill_ms: 0.0,
            decode_ms: 0.0,
            queue_ms: 0.0,
            density: 1.0,
            degraded: false,
            effective_density: 1.0,
            refreshes: 0,
            mask_updates: 0,
            finish: String::new(),
            error: Some(msg),
        }
    }

    /// The response's JSON document (the v1 line body; the v2 `done`
    /// frame carries exactly these fields plus `v`/`ev`).
    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("id", Json::Num(self.id as f64));
        if let Some(e) = &self.error {
            o.set("error", Json::Str(e.clone()));
        } else {
            o.set("text", Json::Str(self.text.clone()))
                .set("tokens", Json::Num(self.tokens as f64))
                .set("prompt_tokens", Json::Num(self.prompt_tokens as f64))
                .set(
                    "cached_prompt_tokens",
                    Json::Num(self.cached_prompt_tokens as f64),
                )
                .set("cache_hits", Json::Num(self.cache_hits as f64))
                .set(
                    "cache_evictions",
                    Json::Num(self.cache_evictions as f64),
                )
                .set("prefill_ms", Json::Num(self.prefill_ms))
                .set("decode_ms", Json::Num(self.decode_ms))
                .set("queue_ms", Json::Num(self.queue_ms))
                .set("density", Json::Num(self.density))
                .set("degraded", Json::Bool(self.degraded))
                .set(
                    "effective_density",
                    Json::Num(self.effective_density),
                )
                .set("refreshes", Json::Num(self.refreshes as f64))
                .set("mask_updates", Json::Num(self.mask_updates as f64))
                .set("finish", Json::Str(self.finish.clone()));
        }
        o
    }

    /// v1 response line.
    pub fn to_line(&self) -> String {
        self.to_json().to_string()
    }

    /// Build from an already-parsed document (ignores unknown keys, so
    /// a v2 `done` frame parses through the same path).
    pub fn from_json(j: &Json) -> Result<Response> {
        let id = j.req("id")?.as_usize()? as u64;
        if let Some(e) = j.get("error") {
            return Ok(Response::err(id, e.as_str()?.to_string()));
        }
        let get_f = |k: &str, d: f64| -> Result<f64> {
            match j.get(k) {
                Some(v) => v.as_f64(),
                None => Ok(d),
            }
        };
        let get_u = |k: &str, d: usize| -> Result<usize> {
            match j.get(k) {
                Some(v) => v.as_usize(),
                None => Ok(d),
            }
        };
        Ok(Response {
            id,
            text: j.req("text")?.as_str()?.to_string(),
            tokens: j.req("tokens")?.as_usize()?,
            prompt_tokens: get_u("prompt_tokens", 0)?,
            cached_prompt_tokens: get_u("cached_prompt_tokens", 0)?,
            cache_hits: get_u("cache_hits", 0)?,
            cache_evictions: get_u("cache_evictions", 0)?,
            prefill_ms: j.req("prefill_ms")?.as_f64()?,
            decode_ms: j.req("decode_ms")?.as_f64()?,
            queue_ms: get_f("queue_ms", 0.0)?,
            density: j.req("density")?.as_f64()?,
            // pre-governor servers emit neither field: an un-degraded
            // response served exactly at its reported density
            degraded: match j.get("degraded") {
                Some(v) => v.as_bool()?,
                None => false,
            },
            effective_density: get_f(
                "effective_density",
                j.req("density")?.as_f64()?,
            )?,
            refreshes: get_u("refreshes", 0)?,
            mask_updates: get_u("mask_updates", 0)?,
            finish: match j.get("finish") {
                Some(v) => v.as_str()?.to_string(),
                None => "length".to_string(),
            },
            error: None,
        })
    }

    /// Parse one raw v1 response line.
    pub fn parse(line: &str) -> Result<Response> {
        Response::from_json(&Json::parse(line)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_roundtrip() {
        let r = Request {
            id: 3,
            prompt: "once there was a \"fox\"".into(),
            strategy: "a-glass".into(),
            lambda: 0.5,
            density: 0.4,
            max_tokens: 32,
            refresh_every: 8,
            cache: CacheMode::ReadOnly,
            tier: Tier::Interactive,
        };
        let r2 = Request::parse(&r.to_line()).unwrap();
        assert_eq!(r, r2);
    }

    #[test]
    fn request_defaults() {
        let r = Request::parse(r#"{"id":1,"prompt":"hi"}"#).unwrap();
        assert_eq!(r.strategy, "i-glass");
        assert_eq!(r.max_tokens, 64);
        assert_eq!(r.density, 0.5);
        assert_eq!(r.refresh_every, 0, "refresh defaults to off");
        assert_eq!(r.cache, CacheMode::On, "cache defaults to on");
        assert_eq!(r.tier, Tier::Standard, "tier defaults to standard");
    }

    #[test]
    fn tier_parsed_and_validated() {
        for (s, t) in [
            ("interactive", Tier::Interactive),
            ("standard", Tier::Standard),
            ("batch", Tier::Batch),
        ] {
            let line =
                format!(r#"{{"id":1,"prompt":"x","tier":"{s}"}}"#);
            assert_eq!(Request::parse(&line).unwrap().tier, t);
            assert_eq!(Tier::parse(t.as_str()).unwrap(), t);
        }
        let err =
            Request::parse(r#"{"id":1,"prompt":"x","tier":"vip"}"#)
                .unwrap_err();
        assert!(err.to_string().contains("tier"), "{err}");
        // tiers drain interactive-first
        assert!(Tier::Interactive.rank() < Tier::Standard.rank());
        assert!(Tier::Standard.rank() < Tier::Batch.rank());
    }

    #[test]
    fn cache_mode_parsed_and_validated() {
        for (s, m) in [
            ("on", CacheMode::On),
            ("off", CacheMode::Off),
            ("readonly", CacheMode::ReadOnly),
        ] {
            let line =
                format!(r#"{{"id":1,"prompt":"x","cache":"{s}"}}"#);
            assert_eq!(Request::parse(&line).unwrap().cache, m);
        }
        let err = Request::parse(
            r#"{"id":1,"prompt":"x","cache":"maybe"}"#,
        )
        .unwrap_err();
        assert!(err.to_string().contains("cache mode"), "{err}");
    }

    #[test]
    fn stats_command_parses_and_roundtrips() {
        match parse_client_line(r#"{"cmd":"stats","id":5}"#).unwrap() {
            ClientLine::Stats { id } => assert_eq!(id, 5),
            other => panic!("expected stats, got {other:?}"),
        }
        // id defaults to 0; unknown commands are protocol errors
        assert_eq!(
            parse_client_line(r#"{"cmd":"stats"}"#).unwrap(),
            ClientLine::Stats { id: 0 }
        );
        assert!(parse_client_line(r#"{"cmd":"dance"}"#).is_err());
        // a plain request still parses through the same entry point
        match parse_client_line(r#"{"id":1,"prompt":"hi"}"#).unwrap() {
            ClientLine::Request(r) => assert_eq!(r.id, 1),
            other => panic!("expected request, got {other:?}"),
        }

        let snap = CacheStatsSnapshot {
            hits: 3,
            misses: 2,
            inserts: 4,
            evictions: 1,
            bytes_resident: 4096,
            entries: 3,
            warm_start_hits: 2,
        };
        let shards = vec![
            ShardSnapshot {
                shard: 0,
                queue_depth: 2,
                slots_active: 3,
                slots_prefilling: 1,
                batch_width: 4,
                governor_level: 2,
                degraded_requests: 5,
                stolen_requests: 0,
            },
            ShardSnapshot {
                shard: 1,
                queue_depth: 0,
                slots_active: 0,
                slots_prefilling: 0,
                batch_width: 4,
                governor_level: 0,
                degraded_requests: 0,
                stolen_requests: 3,
            },
        ];
        let (id, back, back_shards) =
            parse_stats_line(&stats_to_line(9, &snap, &shards)).unwrap();
        assert_eq!(id, 9);
        assert_eq!(back, snap);
        assert_eq!(back_shards, shards);
    }

    #[test]
    fn stats_line_without_shards_key_still_parses() {
        // a pre-sharding server's stats line has no "shards" array
        let legacy = r#"{"id":4,"stats":{"cache_hits":7}}"#;
        let (id, snap, shards) = parse_stats_line(legacy).unwrap();
        assert_eq!(id, 4);
        assert_eq!(snap.hits, 7);
        assert_eq!(snap.misses, 0);
        assert_eq!(snap.warm_start_hits, 0, "pre-warm-start default");
        assert!(shards.is_empty());
    }

    #[test]
    fn bad_strategy_rejected() {
        assert!(Request::parse(
            r#"{"id":1,"prompt":"x","strategy":"bogus"}"#
        )
        .is_err());
    }

    #[test]
    fn density_out_of_range_rejected_at_parse() {
        for bad in ["0", "-0.5", "1.5", "0.0"] {
            let line =
                format!(r#"{{"id":1,"prompt":"x","density":{bad}}}"#);
            let err = Request::parse(&line).unwrap_err();
            assert!(
                err.to_string().contains("density"),
                "{bad}: {err}"
            );
        }
        // boundary: exactly 1.0 is dense and legal
        assert!(Request::parse(r#"{"id":1,"prompt":"x","density":1.0}"#)
            .is_ok());
    }

    #[test]
    fn lambda_out_of_range_rejected_at_parse() {
        for bad in ["-0.1", "1.01", "7"] {
            let line =
                format!(r#"{{"id":1,"prompt":"x","lambda":{bad}}}"#);
            let err = Request::parse(&line).unwrap_err();
            assert!(err.to_string().contains("lambda"), "{bad}: {err}");
        }
        for good in ["0", "1", "0.5"] {
            let line =
                format!(r#"{{"id":1,"prompt":"x","lambda":{good}}}"#);
            assert!(Request::parse(&line).is_ok(), "{good}");
        }
    }

    #[test]
    fn zero_max_tokens_rejected_at_parse() {
        let err =
            Request::parse(r#"{"id":1,"prompt":"x","max_tokens":0}"#)
                .unwrap_err();
        assert!(err.to_string().contains("max_tokens"), "{err}");
        assert!(
            Request::parse(r#"{"id":1,"prompt":"x","max_tokens":1}"#)
                .is_ok()
        );
    }

    #[test]
    fn response_roundtrip_ok_and_err() {
        let mut ok = Response::ok(1, "hello".into(), 5, 1.5, 20.0, 0.5);
        ok.prompt_tokens = 25;
        ok.cached_prompt_tokens = 20;
        ok.cache_hits = 1;
        ok.cache_evictions = 2;
        ok.queue_ms = 0.25;
        ok.degraded = true;
        ok.effective_density = 0.35;
        ok.refreshes = 3;
        ok.mask_updates = 1;
        ok.finish = "stop".into();
        assert_eq!(Response::parse(&ok.to_line()).unwrap(), ok);
        let e = Response::err(2, "boom".into());
        let e2 = Response::parse(&e.to_line()).unwrap();
        assert_eq!(e2.error.as_deref(), Some("boom"));
        assert_eq!(e2, e);
    }

    #[test]
    fn legacy_response_without_new_fields_parses() {
        let r = Response::parse(
            r#"{"id":9,"text":"t","tokens":2,"prefill_ms":1.0,
                "decode_ms":2.0,"density":0.5}"#,
        )
        .unwrap();
        assert_eq!(r.queue_ms, 0.0);
        assert_eq!(r.prompt_tokens, 0);
        assert_eq!(r.cached_prompt_tokens, 0);
        assert_eq!(r.cache_hits, 0);
        assert_eq!(r.cache_evictions, 0);
        assert_eq!(r.refreshes, 0);
        assert_eq!(r.finish, "length");
        assert!(!r.degraded, "pre-governor lines are never degraded");
        assert_eq!(
            r.effective_density, 0.5,
            "effective density defaults to the reported density"
        );
    }

    // -------------------------------------------------- v2 frames

    #[test]
    fn frame_version_detection() {
        let v2 = Json::parse(r#"{"v":2,"cmd":"stats"}"#).unwrap();
        assert_eq!(frame_version(&v2).unwrap(), Some(2));
        let v1 = Json::parse(r#"{"id":1,"prompt":"x"}"#).unwrap();
        assert_eq!(frame_version(&v1).unwrap(), None);
        // an unsupported version is an explicit error at frame parse
        let v3 = Json::parse(r#"{"v":3,"cmd":"stats"}"#).unwrap();
        assert_eq!(frame_version(&v3).unwrap(), Some(3));
        let err = v2_frame_from_json(&v3).unwrap_err();
        assert!(
            err.to_string().contains("unsupported protocol version"),
            "{err}"
        );
    }

    #[test]
    fn v2_generate_frame_roundtrips_and_validates() {
        let r = Request {
            id: 7,
            prompt: "the blue owl".into(),
            strategy: "i-glass".into(),
            lambda: 0.5,
            density: 0.4,
            max_tokens: 16,
            refresh_every: 4,
            cache: CacheMode::On,
            tier: Tier::Batch,
        };
        let j = Json::parse(&r.to_v2_frame()).unwrap();
        match v2_frame_from_json(&j).unwrap() {
            V2Frame::Generate(back) => assert_eq!(back, r),
            other => panic!("expected generate, got {other:?}"),
        }
        // v2 generate goes through the same validation as v1
        let bad = Json::parse(
            r#"{"v":2,"cmd":"generate","id":1,"prompt":"x","density":7}"#,
        )
        .unwrap();
        let err = v2_frame_from_json(&bad).unwrap_err();
        assert!(err.to_string().contains("density"), "{err}");
    }

    #[test]
    fn v2_resume_frame_roundtrips_and_validates() {
        let r = Request {
            id: 7,
            prompt: "the blue owl".into(),
            strategy: "i-glass".into(),
            lambda: 0.5,
            density: 0.4,
            max_tokens: 16,
            refresh_every: 4,
            cache: CacheMode::On,
            tier: Tier::Standard,
        };
        let j = Json::parse(&r.to_v2_resume_frame(12)).unwrap();
        match v2_frame_from_json(&j).unwrap() {
            V2Frame::Resume { req, received } => {
                assert_eq!(req, r);
                assert_eq!(received, 12);
            }
            other => panic!("expected resume, got {other:?}"),
        }
        // resume validates like generate, and `received` is mandatory
        let bad = Json::parse(
            r#"{"v":2,"cmd":"resume","id":1,"prompt":"x",
                "received":0,"density":7}"#,
        )
        .unwrap();
        assert!(v2_frame_from_json(&bad).is_err());
        let missing = Json::parse(
            r#"{"v":2,"cmd":"resume","id":1,"prompt":"x"}"#,
        )
        .unwrap();
        assert!(v2_frame_from_json(&missing).is_err());
    }

    #[test]
    fn v2_control_frames_parse() {
        let j = Json::parse(&cancel_frame(9)).unwrap();
        assert_eq!(
            v2_frame_from_json(&j).unwrap(),
            V2Frame::Cancel { id: 9 }
        );
        let j = Json::parse(&set_frame(9, 4)).unwrap();
        assert_eq!(
            v2_frame_from_json(&j).unwrap(),
            V2Frame::Set {
                id: 9,
                refresh_every: 4
            }
        );
        let j = Json::parse(&stats_frame(3)).unwrap();
        assert_eq!(
            v2_frame_from_json(&j).unwrap(),
            V2Frame::Stats { id: 3 }
        );
        // cancel without an id is malformed; unknown commands error
        let j = Json::parse(r#"{"v":2,"cmd":"cancel"}"#).unwrap();
        assert!(v2_frame_from_json(&j).is_err());
        let j = Json::parse(r#"{"v":2,"cmd":"dance","id":1}"#).unwrap();
        assert!(v2_frame_from_json(&j).is_err());
    }

    #[test]
    fn event_frames_roundtrip() {
        let mut done = Response::ok(7, "hello".into(), 5, 1.0, 2.0, 0.5);
        done.finish = "cancel".into();
        let events = vec![
            Event::Accepted {
                id: 7,
                queue_pos: 3,
            },
            Event::Queue { id: 7, position: 2 },
            Event::Delta {
                id: 7,
                index: 0,
                text: "hel\"lo\n".into(),
            },
            Event::Refresh {
                id: 7,
                refreshes: 2,
                mask_updates: 1,
                changed: true,
            },
            Event::Done(done),
            Event::Error {
                id: 7,
                error: "boom".into(),
                retryable: true,
            },
        ];
        for ev in events {
            let j = Json::parse(&ev.to_frame()).unwrap();
            assert_eq!(
                j.req("v").unwrap().as_usize().unwrap(),
                PROTOCOL_V2
            );
            let back = Event::parse_frame(&j).unwrap();
            assert_eq!(back, ev, "{}", ev.to_frame());
            assert_eq!(back.id(), 7);
        }
    }

    #[test]
    fn v1_shim_keeps_terminal_events_only() {
        let done = Response::ok(1, "t".into(), 1, 0.0, 0.0, 1.0);
        assert_eq!(
            Event::Done(done.clone()).into_response(),
            Some(done)
        );
        let err = Event::Error {
            id: 4,
            error: "nope".into(),
            retryable: false,
        }
        .into_response()
        .unwrap();
        // the shim serializes errors exactly as the pre-v2 server did
        assert_eq!(err.to_line(), r#"{"id":4,"error":"nope"}"#);
        assert!(Event::Accepted { id: 1, queue_pos: 0 }
            .into_response()
            .is_none());
        // a pre-queue-frame v2 client's blocking call sees no change
        assert!(Event::Queue { id: 1, position: 4 }
            .into_response()
            .is_none());
        assert!(Event::Delta {
            id: 1,
            index: 0,
            text: "x".into()
        }
        .into_response()
        .is_none());
        assert!(Event::Refresh {
            id: 1,
            refreshes: 1,
            mask_updates: 0,
            changed: false
        }
        .into_response()
        .is_none());
    }

    #[test]
    fn terminality_is_exactly_done_or_error() {
        assert!(Event::Done(Response::err(1, "e".into())).is_terminal());
        assert!(Event::Error {
            id: 1,
            error: "e".into(),
            retryable: false
        }
        .is_terminal());
        assert!(!Event::Accepted { id: 1, queue_pos: 0 }.is_terminal());
        assert!(!Event::Queue { id: 1, position: 0 }.is_terminal());
        assert!(!Event::Delta {
            id: 1,
            index: 0,
            text: String::new()
        }
        .is_terminal());
    }
}
