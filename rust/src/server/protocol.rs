//! JSON-line wire protocol for the serving layer.
//!
//! One JSON object per line in each direction over TCP:
//!   request:  {"id": 7, "prompt": "...", "strategy": "i-glass",
//!              "lambda": 0.5, "density": 0.5, "max_tokens": 64,
//!              "refresh_every": 8, "cache": "on"}
//!   response: {"id": 7, "text": "...", "tokens": 42,
//!              "prompt_tokens": 25, "cached_prompt_tokens": 20,
//!              "cache_hits": 1, "cache_evictions": 0,
//!              "prefill_ms": 1.2,
//!              "decode_ms": 30.5, "queue_ms": 0.3, "density": 0.5,
//!              "refreshes": 5, "mask_updates": 2, "finish": "length"}
//!   error:    {"id": 7, "error": "..."}
//!   command:  {"cmd": "stats", "id": 3}
//!             → {"id": 3, "stats": {"cache_hits": ..., ...},
//!                "shards": [{"shard": 0, "queue_depth": ...,
//!                            "slots_active": ...,
//!                            "slots_prefilling": ...,
//!                            "batch_width": ...}, ...]}
//!
//! Field ranges are validated at parse time and rejected with an
//! immediate protocol error (never surfaced as a deep engine failure):
//! `density` must lie in (0, 1], `lambda` in [0, 1], `max_tokens`
//! must be ≥ 1, and `cache` must be one of on|off|readonly.
//!
//! **Shared-prefix cache.** `cache` selects the request's cache
//! behavior (`on` = read + publish, default; `readonly` = read but
//! never insert; `off` = bypass). `cached_prompt_tokens` reports how
//! many prompt tokens were spliced from the cache instead of being
//! recomputed, `cache_hits` how many cache entries this request used,
//! and `cache_evictions` how many entries this request's own inserts
//! evicted. The `stats` command returns the **server-level** aggregate
//! counters (hits, misses, inserts, evictions, bytes resident, entry
//! count — summed across every shard's cache) so operators can watch
//! cache health without scraping per-response telemetry, plus one
//! [`ShardSnapshot`] per serving shard: live queue depth and decode /
//! prefill slot occupancy, so a routing imbalance is visible from the
//! wire.
//!
//! **Prompt length.** Prompts are NOT bounded by the prefill frame: the
//! batcher streams long prompts through chunked prefill (one chunk per
//! decode step — see [`super::batcher`]), so any prompt whose encoded
//! length plus `max_tokens` fits the serving capacity of `max_seq + 1`
//! (the `max_seq`-position KV window plus one final token that needs no
//! KV write) is served in full. Beyond that the request is rejected
//! with an explicit "prompt too long" error — prompt tokens are never
//! silently dropped.
//! `prompt_tokens` in the response reports how many prompt tokens
//! (incl. BOS) were actually prefilled, so a client can verify its
//! prompt was consumed whole.
//!
//! `refresh_every` = R re-runs the GLASS mask selection every R decoded
//! tokens from blended prompt+decode statistics (0 = static prefill
//! mask). `finish` is "length" (max_tokens / KV window) or "stop"
//! (special token). `mask_updates` counts refreshes that changed the
//! kept set — a direct observable for decode-time importance drift.

use anyhow::{bail, Result};

use crate::engine::prefix_cache::{CacheMode, CacheStatsSnapshot};
use crate::util::json::Json;

/// Strategy names the serving layer accepts.
pub const STRATEGIES: &[&str] =
    &["dense", "griffin", "global", "a-glass", "i-glass"];

#[derive(Debug, Clone, PartialEq)]
pub struct Request {
    pub id: u64,
    pub prompt: String,
    /// One of [`STRATEGIES`].
    pub strategy: String,
    pub lambda: f64,
    pub density: f64,
    pub max_tokens: usize,
    /// Refresh the GLASS mask every N decoded tokens (0 = never).
    pub refresh_every: usize,
    /// Shared-prefix cache behavior for this request.
    pub cache: CacheMode,
}

/// One parsed client line: a generation request or a server command.
#[derive(Debug, Clone, PartialEq)]
pub enum ClientLine {
    Request(Request),
    /// `{"cmd": "stats"}` — report server-level cache counters.
    Stats { id: u64 },
}

/// Parse one client line, dispatching on the optional `cmd` key. The
/// document is parsed ONCE and shared with [`Request::from_json`] —
/// this sits on the per-line hot path of every connection thread.
pub fn parse_client_line(line: &str) -> Result<ClientLine> {
    let j = Json::parse(line)?;
    let Some(cmd) = j.get("cmd") else {
        return Request::from_json(&j).map(ClientLine::Request);
    };
    let id = match j.get("id") {
        Some(v) => v.as_usize()? as u64,
        None => 0,
    };
    match cmd.as_str()? {
        "stats" => Ok(ClientLine::Stats { id }),
        other => bail!("unknown command '{other}'"),
    }
}

/// One serving shard's live counters, as reported by the `stats`
/// command: scheduler queue depth plus decode / prefill slot occupancy
/// (gauges the shard's batcher publishes every loop iteration).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct ShardSnapshot {
    /// Shard index (also the routing target of `route_shard`).
    pub shard: u64,
    /// Requests waiting in this shard's scheduler queue.
    pub queue_depth: u64,
    /// Slots currently decoding.
    pub slots_active: u64,
    /// Slots currently streaming a chunked prefill.
    pub slots_prefilling: u64,
    /// Slot capacity (occupancy denominator).
    pub batch_width: u64,
}

/// Serialize the `stats` command response line: aggregate cache
/// counters plus one entry per serving shard.
pub fn stats_to_line(
    id: u64,
    s: &CacheStatsSnapshot,
    shards: &[ShardSnapshot],
) -> String {
    let mut inner = Json::obj();
    inner
        .set("cache_hits", Json::Num(s.hits as f64))
        .set("cache_misses", Json::Num(s.misses as f64))
        .set("cache_inserts", Json::Num(s.inserts as f64))
        .set("cache_evictions", Json::Num(s.evictions as f64))
        .set("cache_bytes_resident", Json::Num(s.bytes_resident as f64))
        .set("cache_entries", Json::Num(s.entries as f64));
    let per_shard: Vec<Json> = shards
        .iter()
        .map(|sh| {
            let mut o = Json::obj();
            o.set("shard", Json::Num(sh.shard as f64))
                .set("queue_depth", Json::Num(sh.queue_depth as f64))
                .set("slots_active", Json::Num(sh.slots_active as f64))
                .set(
                    "slots_prefilling",
                    Json::Num(sh.slots_prefilling as f64),
                )
                .set("batch_width", Json::Num(sh.batch_width as f64));
            o
        })
        .collect();
    let mut o = Json::obj();
    o.set("id", Json::Num(id as f64))
        .set("stats", inner)
        .set("shards", Json::Arr(per_shard));
    o.to_string()
}

/// Parse a `stats` response line back into the aggregate snapshot and
/// the per-shard counters (client side). A line without a `shards` key
/// (pre-sharding server) parses to an empty shard list.
pub fn parse_stats_line(
    line: &str,
) -> Result<(u64, CacheStatsSnapshot, Vec<ShardSnapshot>)> {
    let j = Json::parse(line)?;
    let id = j.req("id")?.as_usize()? as u64;
    let s = j.req("stats")?;
    let get = |doc: &Json, k: &str| -> Result<u64> {
        Ok(match doc.get(k) {
            Some(v) => v.as_usize()? as u64,
            None => 0,
        })
    };
    let snap = CacheStatsSnapshot {
        hits: get(s, "cache_hits")?,
        misses: get(s, "cache_misses")?,
        inserts: get(s, "cache_inserts")?,
        evictions: get(s, "cache_evictions")?,
        bytes_resident: get(s, "cache_bytes_resident")?,
        entries: get(s, "cache_entries")?,
    };
    let shards = match j.get("shards") {
        Some(arr) => arr
            .as_arr()?
            .iter()
            .map(|sh| {
                Ok(ShardSnapshot {
                    shard: get(sh, "shard")?,
                    queue_depth: get(sh, "queue_depth")?,
                    slots_active: get(sh, "slots_active")?,
                    slots_prefilling: get(sh, "slots_prefilling")?,
                    batch_width: get(sh, "batch_width")?,
                })
            })
            .collect::<Result<Vec<ShardSnapshot>>>()?,
        None => Vec::new(),
    };
    Ok((id, snap, shards))
}

impl Request {
    pub fn parse(line: &str) -> Result<Request> {
        Request::from_json(&Json::parse(line)?)
    }

    /// Build from an already-parsed document (shared with
    /// [`parse_client_line`] so request lines are parsed once).
    pub fn from_json(j: &Json) -> Result<Request> {
        let get_f = |k: &str, d: f64| -> Result<f64> {
            match j.get(k) {
                Some(v) => v.as_f64(),
                None => Ok(d),
            }
        };
        let get_u = |k: &str, d: usize| -> Result<usize> {
            match j.get(k) {
                Some(v) => v.as_usize(),
                None => Ok(d),
            }
        };
        let strategy = match j.get("strategy") {
            Some(v) => v.as_str()?.to_string(),
            None => "i-glass".to_string(),
        };
        if !STRATEGIES.contains(&strategy.as_str()) {
            bail!("unknown strategy '{strategy}'");
        }
        // range-validate numeric knobs here so a bad request dies as an
        // immediate protocol error, not a deep engine failure mid-batch
        let lambda = get_f("lambda", 0.5)?;
        if !(0.0..=1.0).contains(&lambda) {
            bail!("lambda {lambda} outside [0, 1]");
        }
        let density = get_f("density", 0.5)?;
        if !(density > 0.0 && density <= 1.0) {
            bail!("density {density} outside (0, 1]");
        }
        let max_tokens = get_u("max_tokens", 64)?;
        if max_tokens == 0 {
            bail!("max_tokens must be >= 1");
        }
        let cache = match j.get("cache") {
            Some(v) => CacheMode::parse(v.as_str()?)?,
            None => CacheMode::On,
        };
        Ok(Request {
            id: j.req("id")?.as_usize()? as u64,
            prompt: j.req("prompt")?.as_str()?.to_string(),
            strategy,
            lambda,
            density,
            max_tokens,
            refresh_every: get_u("refresh_every", 0)?,
            cache,
        })
    }

    pub fn to_line(&self) -> String {
        let mut o = Json::obj();
        o.set("id", Json::Num(self.id as f64))
            .set("prompt", Json::Str(self.prompt.clone()))
            .set("strategy", Json::Str(self.strategy.clone()))
            .set("lambda", Json::Num(self.lambda))
            .set("density", Json::Num(self.density))
            .set("max_tokens", Json::Num(self.max_tokens as f64))
            .set("refresh_every", Json::Num(self.refresh_every as f64))
            .set("cache", Json::Str(self.cache.as_str().to_string()));
        o.to_string()
    }
}

#[derive(Debug, Clone, PartialEq)]
pub struct Response {
    pub id: u64,
    pub text: String,
    pub tokens: usize,
    /// Prompt tokens actually prefilled (incl. BOS). Lets a client
    /// distinguish a full-prompt response from a truncated one — the
    /// engine never truncates silently, and this field proves it.
    pub prompt_tokens: usize,
    /// Prompt tokens spliced from the shared-prefix cache instead of
    /// being recomputed (0 = cold prefill or cache off).
    pub cached_prompt_tokens: usize,
    /// Cache entries this request used (0 or 1 today).
    pub cache_hits: usize,
    /// Entries this request's own cache inserts evicted.
    pub cache_evictions: usize,
    pub prefill_ms: f64,
    pub decode_ms: f64,
    /// Time spent queued before admission into a batch slot.
    pub queue_ms: f64,
    pub density: f64,
    /// Mask refreshes applied / refreshes that changed the kept set.
    pub refreshes: usize,
    pub mask_updates: usize,
    /// "length" | "stop" ("" on errors).
    pub finish: String,
    pub error: Option<String>,
}

impl Response {
    pub fn ok(
        id: u64,
        text: String,
        tokens: usize,
        prefill_ms: f64,
        decode_ms: f64,
        density: f64,
    ) -> Response {
        Response {
            id,
            text,
            tokens,
            prompt_tokens: 0,
            cached_prompt_tokens: 0,
            cache_hits: 0,
            cache_evictions: 0,
            prefill_ms,
            decode_ms,
            queue_ms: 0.0,
            density,
            refreshes: 0,
            mask_updates: 0,
            finish: "length".to_string(),
            error: None,
        }
    }

    pub fn err(id: u64, msg: String) -> Response {
        Response {
            id,
            text: String::new(),
            tokens: 0,
            prompt_tokens: 0,
            cached_prompt_tokens: 0,
            cache_hits: 0,
            cache_evictions: 0,
            prefill_ms: 0.0,
            decode_ms: 0.0,
            queue_ms: 0.0,
            density: 1.0,
            refreshes: 0,
            mask_updates: 0,
            finish: String::new(),
            error: Some(msg),
        }
    }

    pub fn to_line(&self) -> String {
        let mut o = Json::obj();
        o.set("id", Json::Num(self.id as f64));
        if let Some(e) = &self.error {
            o.set("error", Json::Str(e.clone()));
        } else {
            o.set("text", Json::Str(self.text.clone()))
                .set("tokens", Json::Num(self.tokens as f64))
                .set("prompt_tokens", Json::Num(self.prompt_tokens as f64))
                .set(
                    "cached_prompt_tokens",
                    Json::Num(self.cached_prompt_tokens as f64),
                )
                .set("cache_hits", Json::Num(self.cache_hits as f64))
                .set(
                    "cache_evictions",
                    Json::Num(self.cache_evictions as f64),
                )
                .set("prefill_ms", Json::Num(self.prefill_ms))
                .set("decode_ms", Json::Num(self.decode_ms))
                .set("queue_ms", Json::Num(self.queue_ms))
                .set("density", Json::Num(self.density))
                .set("refreshes", Json::Num(self.refreshes as f64))
                .set("mask_updates", Json::Num(self.mask_updates as f64))
                .set("finish", Json::Str(self.finish.clone()));
        }
        o.to_string()
    }

    pub fn parse(line: &str) -> Result<Response> {
        let j = Json::parse(line)?;
        let id = j.req("id")?.as_usize()? as u64;
        if let Some(e) = j.get("error") {
            return Ok(Response::err(id, e.as_str()?.to_string()));
        }
        let get_f = |k: &str, d: f64| -> Result<f64> {
            match j.get(k) {
                Some(v) => v.as_f64(),
                None => Ok(d),
            }
        };
        let get_u = |k: &str, d: usize| -> Result<usize> {
            match j.get(k) {
                Some(v) => v.as_usize(),
                None => Ok(d),
            }
        };
        Ok(Response {
            id,
            text: j.req("text")?.as_str()?.to_string(),
            tokens: j.req("tokens")?.as_usize()?,
            prompt_tokens: get_u("prompt_tokens", 0)?,
            cached_prompt_tokens: get_u("cached_prompt_tokens", 0)?,
            cache_hits: get_u("cache_hits", 0)?,
            cache_evictions: get_u("cache_evictions", 0)?,
            prefill_ms: j.req("prefill_ms")?.as_f64()?,
            decode_ms: j.req("decode_ms")?.as_f64()?,
            queue_ms: get_f("queue_ms", 0.0)?,
            density: j.req("density")?.as_f64()?,
            refreshes: get_u("refreshes", 0)?,
            mask_updates: get_u("mask_updates", 0)?,
            finish: match j.get("finish") {
                Some(v) => v.as_str()?.to_string(),
                None => "length".to_string(),
            },
            error: None,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_roundtrip() {
        let r = Request {
            id: 3,
            prompt: "once there was a \"fox\"".into(),
            strategy: "a-glass".into(),
            lambda: 0.5,
            density: 0.4,
            max_tokens: 32,
            refresh_every: 8,
            cache: CacheMode::ReadOnly,
        };
        let r2 = Request::parse(&r.to_line()).unwrap();
        assert_eq!(r, r2);
    }

    #[test]
    fn request_defaults() {
        let r = Request::parse(r#"{"id":1,"prompt":"hi"}"#).unwrap();
        assert_eq!(r.strategy, "i-glass");
        assert_eq!(r.max_tokens, 64);
        assert_eq!(r.density, 0.5);
        assert_eq!(r.refresh_every, 0, "refresh defaults to off");
        assert_eq!(r.cache, CacheMode::On, "cache defaults to on");
    }

    #[test]
    fn cache_mode_parsed_and_validated() {
        for (s, m) in [
            ("on", CacheMode::On),
            ("off", CacheMode::Off),
            ("readonly", CacheMode::ReadOnly),
        ] {
            let line =
                format!(r#"{{"id":1,"prompt":"x","cache":"{s}"}}"#);
            assert_eq!(Request::parse(&line).unwrap().cache, m);
        }
        let err = Request::parse(
            r#"{"id":1,"prompt":"x","cache":"maybe"}"#,
        )
        .unwrap_err();
        assert!(err.to_string().contains("cache mode"), "{err}");
    }

    #[test]
    fn stats_command_parses_and_roundtrips() {
        match parse_client_line(r#"{"cmd":"stats","id":5}"#).unwrap() {
            ClientLine::Stats { id } => assert_eq!(id, 5),
            other => panic!("expected stats, got {other:?}"),
        }
        // id defaults to 0; unknown commands are protocol errors
        assert_eq!(
            parse_client_line(r#"{"cmd":"stats"}"#).unwrap(),
            ClientLine::Stats { id: 0 }
        );
        assert!(parse_client_line(r#"{"cmd":"dance"}"#).is_err());
        // a plain request still parses through the same entry point
        match parse_client_line(r#"{"id":1,"prompt":"hi"}"#).unwrap() {
            ClientLine::Request(r) => assert_eq!(r.id, 1),
            other => panic!("expected request, got {other:?}"),
        }

        let snap = CacheStatsSnapshot {
            hits: 3,
            misses: 2,
            inserts: 4,
            evictions: 1,
            bytes_resident: 4096,
            entries: 3,
        };
        let shards = vec![
            ShardSnapshot {
                shard: 0,
                queue_depth: 2,
                slots_active: 3,
                slots_prefilling: 1,
                batch_width: 4,
            },
            ShardSnapshot {
                shard: 1,
                queue_depth: 0,
                slots_active: 0,
                slots_prefilling: 0,
                batch_width: 4,
            },
        ];
        let (id, back, back_shards) =
            parse_stats_line(&stats_to_line(9, &snap, &shards)).unwrap();
        assert_eq!(id, 9);
        assert_eq!(back, snap);
        assert_eq!(back_shards, shards);
    }

    #[test]
    fn stats_line_without_shards_key_still_parses() {
        // a pre-sharding server's stats line has no "shards" array
        let legacy = r#"{"id":4,"stats":{"cache_hits":7}}"#;
        let (id, snap, shards) = parse_stats_line(legacy).unwrap();
        assert_eq!(id, 4);
        assert_eq!(snap.hits, 7);
        assert_eq!(snap.misses, 0);
        assert!(shards.is_empty());
    }

    #[test]
    fn bad_strategy_rejected() {
        assert!(Request::parse(
            r#"{"id":1,"prompt":"x","strategy":"bogus"}"#
        )
        .is_err());
    }

    #[test]
    fn density_out_of_range_rejected_at_parse() {
        for bad in ["0", "-0.5", "1.5", "0.0"] {
            let line =
                format!(r#"{{"id":1,"prompt":"x","density":{bad}}}"#);
            let err = Request::parse(&line).unwrap_err();
            assert!(
                err.to_string().contains("density"),
                "{bad}: {err}"
            );
        }
        // boundary: exactly 1.0 is dense and legal
        assert!(Request::parse(r#"{"id":1,"prompt":"x","density":1.0}"#)
            .is_ok());
    }

    #[test]
    fn lambda_out_of_range_rejected_at_parse() {
        for bad in ["-0.1", "1.01", "7"] {
            let line =
                format!(r#"{{"id":1,"prompt":"x","lambda":{bad}}}"#);
            let err = Request::parse(&line).unwrap_err();
            assert!(err.to_string().contains("lambda"), "{bad}: {err}");
        }
        for good in ["0", "1", "0.5"] {
            let line =
                format!(r#"{{"id":1,"prompt":"x","lambda":{good}}}"#);
            assert!(Request::parse(&line).is_ok(), "{good}");
        }
    }

    #[test]
    fn zero_max_tokens_rejected_at_parse() {
        let err =
            Request::parse(r#"{"id":1,"prompt":"x","max_tokens":0}"#)
                .unwrap_err();
        assert!(err.to_string().contains("max_tokens"), "{err}");
        assert!(
            Request::parse(r#"{"id":1,"prompt":"x","max_tokens":1}"#)
                .is_ok()
        );
    }

    #[test]
    fn response_roundtrip_ok_and_err() {
        let mut ok = Response::ok(1, "hello".into(), 5, 1.5, 20.0, 0.5);
        ok.prompt_tokens = 25;
        ok.cached_prompt_tokens = 20;
        ok.cache_hits = 1;
        ok.cache_evictions = 2;
        ok.queue_ms = 0.25;
        ok.refreshes = 3;
        ok.mask_updates = 1;
        ok.finish = "stop".into();
        assert_eq!(Response::parse(&ok.to_line()).unwrap(), ok);
        let e = Response::err(2, "boom".into());
        let e2 = Response::parse(&e.to_line()).unwrap();
        assert_eq!(e2.error.as_deref(), Some("boom"));
        assert_eq!(e2, e);
    }

    #[test]
    fn legacy_response_without_new_fields_parses() {
        let r = Response::parse(
            r#"{"id":9,"text":"t","tokens":2,"prefill_ms":1.0,
                "decode_ms":2.0,"density":0.5}"#,
        )
        .unwrap();
        assert_eq!(r.queue_ms, 0.0);
        assert_eq!(r.prompt_tokens, 0);
        assert_eq!(r.cached_prompt_tokens, 0);
        assert_eq!(r.cache_hits, 0);
        assert_eq!(r.cache_evictions, 0);
        assert_eq!(r.refreshes, 0);
        assert_eq!(r.finish, "length");
    }
}
