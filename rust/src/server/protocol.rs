//! JSON-line wire protocol for the serving layer.
//!
//! One JSON object per line in each direction over TCP:
//!   request:  {"id": 7, "prompt": "...", "strategy": "glass",
//!              "lambda": 0.5, "density": 0.5, "max_tokens": 64}
//!   response: {"id": 7, "text": "...", "tokens": 42,
//!              "prefill_ms": 1.2, "decode_ms": 30.5, "density": 0.5}
//!   error:    {"id": 7, "error": "..."}

use anyhow::{bail, Result};

use crate::util::json::Json;

#[derive(Debug, Clone, PartialEq)]
pub struct Request {
    pub id: u64,
    pub prompt: String,
    /// "dense" | "griffin" | "global" | "a-glass" | "i-glass"
    pub strategy: String,
    pub lambda: f64,
    pub density: f64,
    pub max_tokens: usize,
}

impl Request {
    pub fn parse(line: &str) -> Result<Request> {
        let j = Json::parse(line)?;
        let get_f = |k: &str, d: f64| -> Result<f64> {
            match j.get(k) {
                Some(v) => v.as_f64(),
                None => Ok(d),
            }
        };
        let strategy = match j.get("strategy") {
            Some(v) => v.as_str()?.to_string(),
            None => "i-glass".to_string(),
        };
        if !["dense", "griffin", "global", "a-glass", "i-glass"]
            .contains(&strategy.as_str())
        {
            bail!("unknown strategy '{strategy}'");
        }
        Ok(Request {
            id: j.req("id")?.as_usize()? as u64,
            prompt: j.req("prompt")?.as_str()?.to_string(),
            strategy,
            lambda: get_f("lambda", 0.5)?,
            density: get_f("density", 0.5)?,
            max_tokens: match j.get("max_tokens") {
                Some(v) => v.as_usize()?,
                None => 64,
            },
        })
    }

    pub fn to_line(&self) -> String {
        let mut o = Json::obj();
        o.set("id", Json::Num(self.id as f64))
            .set("prompt", Json::Str(self.prompt.clone()))
            .set("strategy", Json::Str(self.strategy.clone()))
            .set("lambda", Json::Num(self.lambda))
            .set("density", Json::Num(self.density))
            .set("max_tokens", Json::Num(self.max_tokens as f64));
        o.to_string()
    }
}

#[derive(Debug, Clone, PartialEq)]
pub struct Response {
    pub id: u64,
    pub text: String,
    pub tokens: usize,
    pub prefill_ms: f64,
    pub decode_ms: f64,
    pub density: f64,
    pub error: Option<String>,
}

impl Response {
    pub fn ok(
        id: u64,
        text: String,
        tokens: usize,
        prefill_ms: f64,
        decode_ms: f64,
        density: f64,
    ) -> Response {
        Response {
            id,
            text,
            tokens,
            prefill_ms,
            decode_ms,
            density,
            error: None,
        }
    }

    pub fn err(id: u64, msg: String) -> Response {
        Response {
            id,
            text: String::new(),
            tokens: 0,
            prefill_ms: 0.0,
            decode_ms: 0.0,
            density: 1.0,
            error: Some(msg),
        }
    }

    pub fn to_line(&self) -> String {
        let mut o = Json::obj();
        o.set("id", Json::Num(self.id as f64));
        if let Some(e) = &self.error {
            o.set("error", Json::Str(e.clone()));
        } else {
            o.set("text", Json::Str(self.text.clone()))
                .set("tokens", Json::Num(self.tokens as f64))
                .set("prefill_ms", Json::Num(self.prefill_ms))
                .set("decode_ms", Json::Num(self.decode_ms))
                .set("density", Json::Num(self.density));
        }
        o.to_string()
    }

    pub fn parse(line: &str) -> Result<Response> {
        let j = Json::parse(line)?;
        let id = j.req("id")?.as_usize()? as u64;
        if let Some(e) = j.get("error") {
            return Ok(Response::err(id, e.as_str()?.to_string()));
        }
        Ok(Response {
            id,
            text: j.req("text")?.as_str()?.to_string(),
            tokens: j.req("tokens")?.as_usize()?,
            prefill_ms: j.req("prefill_ms")?.as_f64()?,
            decode_ms: j.req("decode_ms")?.as_f64()?,
            density: j.req("density")?.as_f64()?,
            error: None,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_roundtrip() {
        let r = Request {
            id: 3,
            prompt: "once there was a \"fox\"".into(),
            strategy: "a-glass".into(),
            lambda: 0.5,
            density: 0.4,
            max_tokens: 32,
        };
        let r2 = Request::parse(&r.to_line()).unwrap();
        assert_eq!(r, r2);
    }

    #[test]
    fn request_defaults() {
        let r = Request::parse(r#"{"id":1,"prompt":"hi"}"#).unwrap();
        assert_eq!(r.strategy, "i-glass");
        assert_eq!(r.max_tokens, 64);
        assert_eq!(r.density, 0.5);
    }

    #[test]
    fn bad_strategy_rejected() {
        assert!(Request::parse(
            r#"{"id":1,"prompt":"x","strategy":"bogus"}"#
        )
        .is_err());
    }

    #[test]
    fn response_roundtrip_ok_and_err() {
        let ok = Response::ok(1, "hello".into(), 5, 1.5, 20.0, 0.5);
        assert_eq!(Response::parse(&ok.to_line()).unwrap(), ok);
        let e = Response::err(2, "boom".into());
        let e2 = Response::parse(&e.to_line()).unwrap();
        assert_eq!(e2.error.as_deref(), Some("boom"));
    }
}
