//! Hot-prefix work-stealing: the bounded cross-shard escape hatch.
//!
//! Prefix-affinity routing (`route_shard`) colocates warm cache hits —
//! and therefore also concentrates a viral prompt on one shard while
//! its siblings idle. When the governor is enabled, the reactor may
//! override the router at admission time: if the home shard's pressure
//! is at or past the steal threshold and a sibling has idle capacity,
//! the sibling **steals** the request ([`plan_steal`]), and the home
//! shard's longest matching cached prefix is replicated into the
//! thief's cache first ([`replicate_prefix`]) so the stolen request
//! still warm-hits.
//!
//! # The bounded crack in "shards never talk"
//!
//! This is the first deliberate exception to the shards-never-share
//! invariant, and it is bounded by construction:
//!
//!  * it runs **only at admission time** on a reactor thread — never
//!    on the per-token decode path;
//!  * the only shared state is each shard's `Arc<Mutex<PrefixCache>>`
//!    handle, and the two locks involved are taken **sequentially,
//!    never nested** (export under the home lock, import under the
//!    thief lock), so no lock-order cycle exists;
//!  * replication is copy-only: the home shard's cache is read, never
//!    mutated, and a failed or skipped import just means the thief
//!    serves the prompt cold — correctness never depends on the copy.

use std::sync::{Arc, Mutex};

use crate::engine::prefix_cache::PrefixCache;

use super::batcher::lock_cache;

/// One shard's live load as sampled by the reactor at admission time
/// (queue depth from the scheduler, occupancy from [`ShardGauges`]).
///
/// [`ShardGauges`]: super::batcher::ShardGauges
#[derive(Debug, Clone, Copy)]
pub struct ShardLoad {
    /// Requests waiting in the shard's scheduler queue.
    pub queued: usize,
    /// Slots currently decoding.
    pub active: usize,
    /// Slots streaming a chunked prefill in.
    pub prefilling: usize,
    /// The shard's batch width (slot capacity).
    pub width: usize,
}

impl ShardLoad {
    /// Outstanding work per slot of capacity — the same normalization
    /// the governor's level thresholds use.
    pub fn pressure(&self) -> f64 {
        (self.queued + self.active + self.prefilling) as f64
            / self.width.max(1) as f64
    }

    /// Can this shard start a newcomer immediately? (empty queue and at
    /// least one free slot)
    pub fn has_idle_capacity(&self) -> bool {
        self.queued == 0 && self.active + self.prefilling < self.width
    }
}

/// Decide whether an admission routed to `home` should be stolen:
/// `Some(thief)` when the home shard's pressure is at or past
/// `threshold` AND some sibling can start the request immediately —
/// the least-loaded such sibling (lowest index on ties). `None` keeps
/// the router's choice (including every single-shard deployment).
pub fn plan_steal(
    home: usize,
    loads: &[ShardLoad],
    threshold: f64,
) -> Option<usize> {
    if loads.len() < 2 {
        return None;
    }
    if loads.get(home)?.pressure() < threshold {
        return None;
    }
    loads
        .iter()
        .enumerate()
        .filter(|&(i, l)| i != home && l.has_idle_capacity())
        .min_by(|(_, a), (_, b)| a.pressure().total_cmp(&b.pressure()))
        .map(|(i, _)| i)
}

/// Replicate the home shard's longest cached prefix of `tokens` into
/// the thief's cache, so the stolen request warm-hits there. Returns
/// the replicated prefix length in tokens (0 = home had nothing to
/// copy, or the copy failed — the thief then serves cold, which is
/// always correct). Locks are taken one at a time, never nested.
pub fn replicate_prefix(
    home: &Arc<Mutex<PrefixCache>>,
    thief: &Arc<Mutex<PrefixCache>>,
    tokens: &[i32],
) -> usize {
    let best = {
        let guard = lock_cache(home);
        if guard.peek_longest(tokens) == 0 {
            return 0;
        }
        // export_hot clones entries, but this path runs only on a
        // saturated-shard admission (rare by construction), never per
        // token
        guard
            .export_hot()
            .into_iter()
            .filter(|(key, _)| tokens.starts_with(key))
            .max_by_key(|(key, _)| key.len())
    };
    let Some((key, seed)) = best else {
        return 0;
    };
    let len = key.len();
    match lock_cache(thief).import_seed(&key, seed) {
        // Ok(false) = duplicate (already replicated earlier) or the
        // thief's budget is full — either way the steal proceeds
        Ok(_) => len,
        Err(e) => {
            crate::warn_!(
                "hot-prefix replication skipped ({len} tokens): {e}"
            );
            0
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn load(queued: usize, active: usize, width: usize) -> ShardLoad {
        ShardLoad { queued, active, prefilling: 0, width }
    }

    #[test]
    fn no_steal_on_single_shard_or_calm_home() {
        assert_eq!(plan_steal(0, &[load(100, 4, 4)], 2.0), None);
        let loads = [load(1, 2, 4), load(0, 0, 4)];
        assert_eq!(
            plan_steal(0, &loads, 2.0),
            None,
            "home pressure 0.75 below threshold"
        );
    }

    #[test]
    fn saturated_home_steals_to_least_loaded_idle_sibling() {
        let loads = [
            load(8, 4, 4),  // home: pressure 3.0
            load(0, 2, 4),  // idle capacity, pressure 0.5
            load(0, 1, 4),  // idle capacity, pressure 0.25 — least
            load(3, 4, 4),  // busy: queued → not idle
        ];
        assert_eq!(plan_steal(0, &loads, 2.0), Some(2));
    }

    #[test]
    fn no_idle_sibling_means_no_steal() {
        let loads = [
            load(8, 4, 4), // home saturated
            load(1, 4, 4), // queued
            load(0, 4, 4), // full width
        ];
        assert_eq!(plan_steal(0, &loads, 2.0), None);
    }

    #[test]
    fn ties_break_toward_the_lowest_index() {
        let loads = [load(9, 4, 4), load(0, 1, 4), load(0, 1, 4)];
        assert_eq!(plan_steal(0, &loads, 2.0), Some(1));
    }

    #[test]
    fn threshold_is_inclusive() {
        let loads = [load(4, 4, 4), load(0, 0, 4)];
        assert_eq!(
            plan_steal(0, &loads, 2.0),
            Some(1),
            "pressure exactly at the threshold steals"
        );
    }
}
