//! Overload governor: SLO-tiered graceful degradation under load.
//!
//! GLASS's core promise is a *tunable* quality/compute dial with zero
//! inference-time overhead. The governor turns that dial automatically:
//! instead of the classic queue-then-shed overload response, a loaded
//! shard serves *more* users slightly sparser — lowering the effective
//! GLASS density and stretching the mask-refresh interval per request
//! class — and restores full quality as pressure drains.
//!
//! # Tiers, levels, and the knob map
//!
//! Every admission carries an SLO tier
//! ([`Tier`](super::protocol::Tier): `interactive` / `standard` /
//! `batch`, default `standard`). Each shard's engine loop feeds the
//! governor a pressure observation per iteration
//! ([`Governor::observe`]): queue depth, slot occupancy, and the age of
//! the oldest queued request, normalized to *load per slot of
//! capacity*. The observation drives a per-shard **degradation level**
//! (0 = healthy .. [`MAX_LEVEL`] = saturated) that steps up and down
//! **with hysteresis** — the up-threshold into a level sits strictly
//! above the down-threshold out of it ([`LEVEL_UP`] / [`LEVEL_DOWN`]),
//! and each observation moves the level at most one step, so a steady
//! load plateau holds one level instead of thrashing masks.
//!
//! The level maps to concrete GLASS knobs at admission time
//! ([`Governor::plan`]): a per-tier effective-density multiplier
//! ([`DENSITY_MULT`]) and a `refresh_every` stretch
//! ([`REFRESH_STRETCH`]). **Batch degrades first, interactive last**:
//! level 1 touches only batch, level 2 adds standard, and only level 3
//! (saturated) mildly degrades interactive. Effective density never
//! drops below the operator's per-tier floor
//! ([`GovernorConfig::floors`]) and never *rises* above what the
//! request asked for. The governor changes *which* knob values a
//! request runs with — never the math: a degraded request is
//! bit-identical to the same request sent explicitly with the degraded
//! values.
//!
//! # Telemetry
//!
//! `degraded_requests` / `stolen_requests` counters and the live
//! `governor_level` gauge are exported per shard through the `stats`
//! protocol command; every degraded response also carries
//! `degraded: true` + its `effective_density`, so the quality trade is
//! observable end to end.

use std::sync::atomic::{AtomicU64, Ordering};

use super::protocol::Tier;

/// Highest degradation level (saturated).
pub const MAX_LEVEL: u64 = 3;

/// Pressure (load per slot of capacity) at or above which the governor
/// steps **up** into level `i`. `LEVEL_UP[0]` is unused (level 0 is the
/// resting state).
pub const LEVEL_UP: [f64; 4] = [0.0, 1.5, 2.5, 4.0];

/// Pressure **below** which the governor steps **down** out of level
/// `i`. Strictly below the matching [`LEVEL_UP`] entry: the gap is the
/// hysteresis band where the current level holds.
pub const LEVEL_DOWN: [f64; 4] = [0.0, 1.0, 2.0, 3.0];

/// Effective-density multiplier per `[level][Tier::rank()]`. Batch
/// (rank 2) degrades first, interactive (rank 0) last and mildly.
pub const DENSITY_MULT: [[f64; 3]; 4] = [
    [1.0, 1.0, 1.0],
    [1.0, 1.0, 0.7],
    [1.0, 0.7, 0.5],
    [0.8, 0.5, 0.4],
];

/// `refresh_every` multiplier per level (applied only to tiers whose
/// density multiplier is below 1.0 at that level; `refresh_every == 0`
/// — refresh disabled — is never touched).
pub const REFRESH_STRETCH: [usize; 4] = [1, 2, 3, 4];

/// Operator-facing governor knobs (see `--governor`,
/// `--governor-floor-*`, `--steal-threshold`).
#[derive(Debug, Clone, PartialEq)]
pub struct GovernorConfig {
    /// Master switch: off = every [`Governor::plan`] is the identity
    /// and the server never steals.
    pub enabled: bool,
    /// Per-tier effective-density floors, indexed by
    /// [`Tier::rank`] (`[interactive, standard, batch]`). Degradation
    /// never pushes a request's density below its tier's floor.
    pub floors: [f64; 3],
    /// Home-shard pressure (load per slot) at or above which an
    /// admission may be stolen by an idle sibling shard.
    pub steal_threshold: f64,
}

impl Default for GovernorConfig {
    fn default() -> GovernorConfig {
        GovernorConfig {
            enabled: false,
            floors: [0.8, 0.5, 0.3],
            steal_threshold: 2.0,
        }
    }
}

/// The admission-time outcome of [`Governor::plan`]: the knob values
/// the request will actually run with.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Plan {
    /// Effective GLASS density (== requested when not degraded).
    pub density: f64,
    /// Effective mask-refresh interval (== requested when not degraded).
    pub refresh_every: usize,
    /// True when either knob differs from what the request asked for.
    pub degraded: bool,
}

#[derive(Debug, Default)]
struct ShardState {
    /// Current degradation level (0..=[`MAX_LEVEL`]).
    level: AtomicU64,
    /// Requests admitted with degraded knobs.
    degraded: AtomicU64,
    /// Requests this shard stole from a saturated sibling.
    stolen: AtomicU64,
    /// Last observed pressure ×1000 (diagnostics).
    pressure_milli: AtomicU64,
}

/// The per-server governor, shared (via `Arc`) between every shard's
/// engine loop (writer of its own shard's level, at most one thread
/// per shard) and the reactor threads (readers, plus the steal
/// counters).
#[derive(Debug)]
pub struct Governor {
    cfg: GovernorConfig,
    shards: Vec<ShardState>,
}

impl Governor {
    /// Build a governor for `n_shards` shards, all at level 0.
    pub fn new(cfg: GovernorConfig, n_shards: usize) -> Governor {
        let shards =
            (0..n_shards.max(1)).map(|_| ShardState::default()).collect();
        Governor { cfg, shards }
    }

    /// Is governance (degradation + stealing) switched on?
    pub fn enabled(&self) -> bool {
        self.cfg.enabled
    }

    /// The governor's configuration (floors, steal threshold).
    pub fn config(&self) -> &GovernorConfig {
        &self.cfg
    }

    fn shard(&self, shard: usize) -> &ShardState {
        // clamp instead of panicking: a shard index is always produced
        // by route_shard/plan_steal over the same shard count, so this
        // only guards hand-built test setups
        &self.shards[shard.min(self.shards.len() - 1)]
    }

    /// Fold one load observation into the shard's degradation level and
    /// return the (possibly stepped) level. Pressure is
    /// `(queued + active + prefilling) / width` plus up to one extra
    /// unit for queue age (1.0 at ≥ 1 s oldest-wait), so a stale queue
    /// escalates even at moderate depth. The level steps **up at most
    /// one level per observation** (re-escalation is gradual) but
    /// **drains as far as the pressure warrants in one step** — the
    /// engine loop may block for work right after observing an empty
    /// queue, and the next admission must not be served at a stale
    /// elevated level. Both directions respect the hysteresis band
    /// ([`LEVEL_UP`] / [`LEVEL_DOWN`]), so a steady plateau holds its
    /// level. Called from the owning shard's engine loop only (single
    /// writer per shard).
    pub fn observe(
        &self,
        shard: usize,
        queued: usize,
        active: usize,
        prefilling: usize,
        width: usize,
        oldest_queue_ms: f64,
    ) -> u64 {
        let outstanding = (queued + active + prefilling) as f64;
        let load = outstanding / width.max(1) as f64;
        let age_boost = (oldest_queue_ms / 1000.0).clamp(0.0, 1.0);
        let pressure = load + age_boost;
        let st = self.shard(shard);
        // Relaxed: the level is a single-writer gauge (this shard's
        // engine thread); readers only need an eventually-current
        // value, no ordering against other memory.
        let level = st.level.load(Ordering::Relaxed);
        let mut next = level;
        if level < MAX_LEVEL && pressure >= LEVEL_UP[(level + 1) as usize]
        {
            next = level + 1;
        } else {
            while next > 0 && pressure < LEVEL_DOWN[next as usize] {
                next -= 1;
            }
        }
        if next != level {
            // Relaxed: same single-writer gauge as the load above.
            st.level.store(next, Ordering::Relaxed);
        }
        // Relaxed: diagnostics-only gauge, no cross-variable ordering.
        st.pressure_milli
            .store((pressure * 1000.0) as u64, Ordering::Relaxed);
        next
    }

    /// The shard's current degradation level.
    pub fn level(&self, shard: usize) -> u64 {
        // Relaxed: gauge read, see observe()
        self.shard(shard).level.load(Ordering::Relaxed)
    }

    /// The shard's last observed pressure (load per slot of capacity).
    pub fn pressure(&self, shard: usize) -> f64 {
        // Relaxed: diagnostics gauge, see observe()
        self.shard(shard).pressure_milli.load(Ordering::Relaxed) as f64
            / 1000.0
    }

    /// Map a request's tier + requested knobs through the shard's
    /// current level. Identity when disabled, at level 0, or when the
    /// level's multiplier leaves this tier alone. Effective density is
    /// clamped to `[tier floor, requested]` — degradation never raises
    /// density and never sinks below the operator's floor; a non-zero
    /// `refresh_every` is stretched by the level's factor.
    pub fn plan(
        &self,
        shard: usize,
        tier: Tier,
        density: f64,
        refresh_every: usize,
    ) -> Plan {
        let identity = Plan {
            density,
            refresh_every,
            degraded: false,
        };
        if !self.cfg.enabled {
            return identity;
        }
        let level = self.level(shard) as usize;
        let mult = DENSITY_MULT[level.min(3)][tier.rank() as usize];
        if mult >= 1.0 {
            return identity;
        }
        let floor = self.cfg.floors[tier.rank() as usize];
        let eff_density = (density * mult).max(floor).min(density);
        let eff_refresh = if refresh_every == 0 {
            0
        } else {
            refresh_every.saturating_mul(REFRESH_STRETCH[level.min(3)])
        };
        let degraded = eff_density < density - 1e-12
            || eff_refresh != refresh_every;
        Plan {
            density: eff_density,
            refresh_every: eff_refresh,
            degraded,
        }
    }

    /// Count one admission that ran with degraded knobs.
    pub fn note_degraded(&self, shard: usize) {
        // Relaxed: monotonic telemetry counter, no ordering needed
        self.shard(shard).degraded.fetch_add(1, Ordering::Relaxed);
    }

    /// Count one admission stolen BY `shard` from a saturated sibling.
    pub fn note_stolen(&self, shard: usize) {
        // Relaxed: monotonic telemetry counter, no ordering needed
        self.shard(shard).stolen.fetch_add(1, Ordering::Relaxed);
    }

    /// Requests this shard admitted with degraded knobs.
    pub fn degraded_requests(&self, shard: usize) -> u64 {
        // Relaxed: telemetry counter read
        self.shard(shard).degraded.load(Ordering::Relaxed)
    }

    /// Requests this shard stole from saturated siblings.
    pub fn stolen_requests(&self, shard: usize) -> u64 {
        // Relaxed: telemetry counter read
        self.shard(shard).stolen.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn on() -> GovernorConfig {
        GovernorConfig {
            enabled: true,
            ..GovernorConfig::default()
        }
    }

    /// observe() with an explicit pressure value (no queue-age boost).
    fn feed(g: &Governor, load_x1000: usize) -> u64 {
        // width 1000 → pressure == load_x1000 / 1000
        g.observe(0, load_x1000, 0, 0, 1000, 0.0)
    }

    #[test]
    fn level_steps_up_one_at_a_time_and_saturates() {
        let g = Governor::new(on(), 1);
        assert_eq!(feed(&g, 9000), 1, "one step per observation");
        assert_eq!(feed(&g, 9000), 2);
        assert_eq!(feed(&g, 9000), 3);
        assert_eq!(feed(&g, 9000), 3, "saturates at MAX_LEVEL");
    }

    #[test]
    fn steady_plateau_holds_one_level_no_oscillation() {
        // the hysteresis satellite: a pressure sitting INSIDE the band
        // (above the down-threshold of the current level, below the
        // up-threshold of the next) must hold the level indefinitely
        let g = Governor::new(on(), 1);
        assert_eq!(feed(&g, 1800), 1, "1.8 ≥ UP[1]=1.5 → level 1");
        for _ in 0..100 {
            assert_eq!(
                feed(&g, 1800),
                1,
                "1.0 ≤ 1.8 < 2.5: plateau holds level 1"
            );
        }
        // and a plateau just under an up-threshold never flickers up
        let g = Governor::new(on(), 1);
        for _ in 0..100 {
            assert_eq!(feed(&g, 1400), 0, "1.4 < UP[1]=1.5 stays level 0");
        }
    }

    #[test]
    fn level_drains_as_far_as_pressure_warrants() {
        let g = Governor::new(on(), 1);
        for _ in 0..3 {
            feed(&g, 9000);
        }
        assert_eq!(g.level(0), 3);
        // partial drain stops inside the first satisfied band:
        // 2.2 < DOWN[3]=3.0 but 2.2 ≥ DOWN[2]=2.0 → level 2
        assert_eq!(feed(&g, 2200), 2);
        // an idle shard resets to 0 in ONE observation — the engine
        // loop blocks for work right after seeing an empty queue, so
        // the post-burst admission must not catch a stale level
        feed(&g, 9000);
        assert_eq!(g.level(0), 3);
        assert_eq!(feed(&g, 0), 0, "full drain in one step");
        assert_eq!(feed(&g, 0), 0, "rests at 0");
    }

    #[test]
    fn hysteresis_band_is_sticky_in_both_directions() {
        // 1.2 lies between DOWN[1]=1.0 and UP[1]=1.5: a shard at level
        // 0 must stay at 0, a shard at level 1 must stay at 1
        let g = Governor::new(on(), 1);
        assert_eq!(feed(&g, 1200), 0);
        feed(&g, 2000); // → level 1
        assert_eq!(g.level(0), 1);
        for _ in 0..50 {
            assert_eq!(feed(&g, 1200), 1);
        }
    }

    #[test]
    fn queue_age_escalates_pressure() {
        let g = Governor::new(on(), 1);
        // load 1.0 alone is below UP[1], but a 1 s oldest-wait adds
        // a full unit of pressure → 2.0 ≥ 1.5
        assert_eq!(g.observe(0, 4, 0, 0, 4, 1000.0), 1);
        // the boost is capped at 1.0 (a 10 s queue is not 10 units)
        let g = Governor::new(on(), 1);
        assert_eq!(g.observe(0, 0, 0, 0, 4, 60_000.0), 0);
    }

    #[test]
    fn plan_degrades_batch_first_interactive_last() {
        let g = Governor::new(on(), 1);
        feed(&g, 9000); // level 1
        let b = g.plan(0, Tier::Batch, 1.0, 8);
        assert!(b.degraded);
        assert!((b.density - 0.7).abs() < 1e-12);
        assert_eq!(b.refresh_every, 16, "stretch ×2 at level 1");
        for tier in [Tier::Interactive, Tier::Standard] {
            let p = g.plan(0, tier, 1.0, 8);
            assert_eq!(
                p,
                Plan { density: 1.0, refresh_every: 8, degraded: false },
                "{tier:?} untouched at level 1"
            );
        }
        feed(&g, 9000); // level 2: standard joins
        assert!(g.plan(0, Tier::Standard, 1.0, 8).degraded);
        assert!(!g.plan(0, Tier::Interactive, 1.0, 8).degraded);
        feed(&g, 9000); // level 3: interactive mildly degraded
        let i = g.plan(0, Tier::Interactive, 1.0, 8);
        assert!(i.degraded);
        assert!(
            i.density >= 0.8 - 1e-12,
            "interactive floor respected: {}",
            i.density
        );
    }

    #[test]
    fn plan_respects_floors_and_never_raises_density() {
        let g = Governor::new(on(), 1);
        for _ in 0..3 {
            feed(&g, 9000); // level 3
        }
        // floor above the multiplied value: clamped up to the floor
        let b = g.plan(0, Tier::Batch, 0.9, 0);
        assert!((b.density - 0.36).abs() < 1e-12, "0.9 × 0.4 above floor");
        let low = g.plan(0, Tier::Batch, 0.2, 0);
        assert!(
            (low.density - 0.2).abs() < 1e-12,
            "a request already below the floor is never raised"
        );
        assert_eq!(low.refresh_every, 0, "refresh 0 (disabled) untouched");
        assert!(
            !low.degraded,
            "nothing changed → not degraded (refresh 0, density kept)"
        );
    }

    #[test]
    fn disabled_governor_is_the_identity() {
        let g = Governor::new(GovernorConfig::default(), 2);
        for _ in 0..5 {
            g.observe(1, 100, 4, 0, 4, 5000.0);
        }
        let p = g.plan(1, Tier::Batch, 0.9, 4);
        assert_eq!(
            p,
            Plan { density: 0.9, refresh_every: 4, degraded: false }
        );
    }

    #[test]
    fn counters_accumulate_per_shard() {
        let g = Governor::new(on(), 2);
        g.note_degraded(0);
        g.note_degraded(0);
        g.note_stolen(1);
        assert_eq!(g.degraded_requests(0), 2);
        assert_eq!(g.degraded_requests(1), 0);
        assert_eq!(g.stolen_requests(1), 1);
        assert_eq!(g.stolen_requests(0), 0);
    }
}
