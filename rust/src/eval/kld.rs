//! Top-100 Kullback-Leibler divergence (App. B.2.2).
//!
//! For each position, restrict both distributions to the 100 tokens with
//! the highest probability under the *dense* reference, renormalize, and
//! compute KL(P_dense ‖ Q_sparse). The dense model vs itself is exactly 0,
//! so reported values quantify deviation from the dense baseline.

use anyhow::{bail, Result};

use crate::tensor::{log_softmax, topk_indices};

/// Top-k KLD between a reference (dense) and a model (sparse) logit row.
pub fn topk_kld(ref_logits: &[f32], model_logits: &[f32], k: usize) -> Result<f64> {
    if ref_logits.len() != model_logits.len() {
        bail!("vocab mismatch");
    }
    if k == 0 {
        bail!("k must be positive");
    }
    let k = k.min(ref_logits.len());
    let support = topk_indices(ref_logits, k);
    let ref_lp = log_softmax(ref_logits);
    let mod_lp = log_softmax(model_logits);

    // renormalize over the support (log-domain)
    let ref_lse = logsumexp_over(&ref_lp, &support);
    let mod_lse = logsumexp_over(&mod_lp, &support);

    let mut kld = 0.0f64;
    for &t in &support {
        let p = (ref_lp[t] - ref_lse) as f64; // log p
        let q = (mod_lp[t] - mod_lse) as f64; // log q
        kld += p.exp() * (p - q);
    }
    Ok(kld.max(0.0))
}

fn logsumexp_over(lp: &[f32], support: &[usize]) -> f32 {
    let m = support
        .iter()
        .map(|&i| lp[i])
        .fold(f32::NEG_INFINITY, f32::max);
    let s: f32 = support.iter().map(|&i| (lp[i] - m).exp()).sum();
    m + s.ln()
}

/// Mean top-k KLD over a sequence of (ref, model) logit row pairs.
pub fn mean_topk_kld(
    ref_rows: &[&[f32]],
    model_rows: &[&[f32]],
    k: usize,
) -> Result<f64> {
    if ref_rows.len() != model_rows.len() || ref_rows.is_empty() {
        bail!("row count mismatch or empty");
    }
    let mut total = 0.0;
    for (r, m) in ref_rows.iter().zip(model_rows) {
        total += topk_kld(r, m, k)?;
    }
    Ok(total / ref_rows.len() as f64)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::prng::Prng;
    use crate::util::quickcheck::{forall, UsizeGen};

    #[test]
    fn identical_distributions_zero() {
        let logits = vec![0.3, -1.0, 2.0, 0.7, -0.2];
        let k = topk_kld(&logits, &logits, 3).unwrap();
        assert!(k.abs() < 1e-9);
    }

    #[test]
    fn diverging_distributions_positive() {
        let r = vec![5.0, 0.0, 0.0, 0.0];
        let m = vec![0.0, 5.0, 0.0, 0.0];
        assert!(topk_kld(&r, &m, 4).unwrap() > 1.0);
    }

    #[test]
    fn k_clamps_to_vocab() {
        let r = vec![1.0, 2.0];
        assert!(topk_kld(&r, &r, 100).unwrap().abs() < 1e-9);
    }

    #[test]
    fn restriction_uses_reference_support() {
        // model puts mass on token 3 which is OUTSIDE the top-2 of ref;
        // restricted KLD only sees tokens 0,1.
        let r = vec![3.0, 2.0, -5.0, -5.0];
        let m = vec![3.0, 2.0, -5.0, 50.0];
        let kld = topk_kld(&r, &m, 2).unwrap();
        assert!(kld.abs() < 1e-5, "kld={kld}");
    }

    #[test]
    fn prop_kld_nonnegative_and_zero_on_self() {
        forall(200, 61, &UsizeGen { lo: 2, hi: 64 }, |&v| {
            let mut rng = Prng::new(v as u64 * 13 + 1);
            let r: Vec<f32> =
                (0..v).map(|_| rng.normal() as f32 * 3.0).collect();
            let m: Vec<f32> =
                (0..v).map(|_| rng.normal() as f32 * 3.0).collect();
            let k = 1 + rng.below(v);
            let kld = topk_kld(&r, &m, k).map_err(|e| e.to_string())?;
            prop_assert!(kld >= 0.0, "negative kld {kld}");
            prop_assert!(kld.is_finite(), "non-finite kld");
            let self_kld =
                topk_kld(&r, &r, k).map_err(|e| e.to_string())?;
            prop_assert!(self_kld.abs() < 1e-6, "self kld {self_kld}");
            Ok(())
        });
    }

    #[test]
    fn mean_over_rows() {
        let a = vec![1.0f32, 0.0];
        let b = vec![0.0f32, 1.0];
        let mean =
            mean_topk_kld(&[&a, &a], &[&a, &b], 2).unwrap();
        let single = topk_kld(&a, &b, 2).unwrap();
        assert!((mean - single / 2.0).abs() < 1e-12);
    }
}
