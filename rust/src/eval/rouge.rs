//! ROUGE-1/2/L (App. B.2.4): n-gram recall with clipped counts, and
//! LCS-based ROUGE-L reported as an F-measure (β = 1).

use std::collections::HashMap;

use super::text_metrics::normalize_answer;

#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct RougeScores {
    pub rouge1: f64,
    pub rouge2: f64,
    pub rouge_l: f64,
}

fn ngrams(tokens: &[String], n: usize) -> HashMap<Vec<&str>, usize> {
    let mut map: HashMap<Vec<&str>, usize> = HashMap::new();
    if tokens.len() < n {
        return map;
    }
    for w in tokens.windows(n) {
        let key: Vec<&str> = w.iter().map(|s| s.as_str()).collect();
        *map.entry(key).or_insert(0) += 1;
    }
    map
}

/// ROUGE-n recall with clipped counts:
/// Σ_g min(count_hyp(g), count_ref(g)) / Σ_g count_ref(g).
pub fn rouge_n(hyp: &str, reference: &str, n: usize) -> f64 {
    let h = normalize_answer(hyp);
    let r = normalize_answer(reference);
    let hg = ngrams(&h, n);
    let rg = ngrams(&r, n);
    let denom: usize = rg.values().sum();
    if denom == 0 {
        return 0.0;
    }
    let mut num = 0usize;
    for (g, rc) in &rg {
        let hc = hg.get(g).copied().unwrap_or(0);
        num += hc.min(*rc);
    }
    num as f64 / denom as f64
}

fn lcs_len(a: &[String], b: &[String]) -> usize {
    let mut dp = vec![0usize; b.len() + 1];
    for x in a {
        let mut prev = 0usize;
        for (j, y) in b.iter().enumerate() {
            let cur = dp[j + 1];
            dp[j + 1] = if x == y {
                prev + 1
            } else {
                dp[j + 1].max(dp[j])
            };
            prev = cur;
        }
    }
    dp[b.len()]
}

/// ROUGE-L F-measure (β = 1): harmonic mean of LCS precision/recall.
pub fn rouge_l(hyp: &str, reference: &str) -> f64 {
    let h = normalize_answer(hyp);
    let r = normalize_answer(reference);
    if h.is_empty() || r.is_empty() {
        return 0.0;
    }
    let l = lcs_len(&h, &r) as f64;
    if l == 0.0 {
        return 0.0;
    }
    let p = l / h.len() as f64;
    let rec = l / r.len() as f64;
    2.0 * p * rec / (p + rec)
}

/// All three scores.
pub fn rouge_all(hyp: &str, reference: &str) -> RougeScores {
    RougeScores {
        rouge1: rouge_n(hyp, reference, 1),
        rouge2: rouge_n(hyp, reference, 2),
        rouge_l: rouge_l(hyp, reference),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identical_text_scores_one() {
        let s = "the quick fox jumps over the dog";
        let r = rouge_all(s, s);
        assert!((r.rouge1 - 1.0).abs() < 1e-12);
        assert!((r.rouge2 - 1.0).abs() < 1e-12);
        assert!((r.rouge_l - 1.0).abs() < 1e-12);
    }

    #[test]
    fn disjoint_text_scores_zero() {
        let r = rouge_all("alpha beta", "gamma delta");
        assert_eq!(r.rouge1, 0.0);
        assert_eq!(r.rouge2, 0.0);
        assert_eq!(r.rouge_l, 0.0);
    }

    #[test]
    fn rouge1_is_unigram_recall() {
        // ref: {red, fox, runs} (articles dropped); hyp covers 2 of 3
        let v = rouge_n("red fox sleeps", "the red fox runs", 1);
        assert!((v - 2.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn rouge2_needs_order() {
        let v = rouge_n("fox red", "red fox", 2);
        assert_eq!(v, 0.0);
        let w = rouge_n("red fox", "red fox", 2);
        assert!((w - 1.0).abs() < 1e-12);
    }

    #[test]
    fn rouge_l_subsequence() {
        // LCS("x c y d z", "c d") = "c d" (len 2); P=2/5, R=1
        let v = rouge_l("x c y d z", "c d");
        let p: f64 = 2.0 / 5.0;
        let expect = 2.0 * p * 1.0 / (p + 1.0);
        assert!((v - expect).abs() < 1e-12);
    }

    #[test]
    fn clipped_counts() {
        // hyp repeats "fox" 3x, ref has it once -> clipped to 1
        let v = rouge_n("fox fox fox", "fox runs", 1);
        assert!((v - 0.5).abs() < 1e-12);
    }

    #[test]
    fn empty_inputs() {
        assert_eq!(rouge_n("", "x", 1), 0.0);
        assert_eq!(rouge_n("x", "", 1), 0.0);
        assert_eq!(rouge_l("", ""), 0.0);
    }
}
