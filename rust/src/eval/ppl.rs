//! Deviation-from-dense perplexity (App. B.2.1).
//!
//! The dense model's greedy generation defines the reference trajectory;
//! PPL measures how unlikely that trajectory is under the *sparsified*
//! model: PPL = exp(−1/N Σ log q(x_i)). The dense model itself scores its
//! own trajectory with low PPL by construction; higher sparse PPL =
//! larger deviation.

use anyhow::{bail, Result};

use crate::tensor::{log_softmax, TensorF};

/// Per-token negative log-likelihoods of `targets[i]` under
/// `logits_rows[i]` (each row is a vocab-sized logit vector).
pub fn nll_per_token(
    logits: &TensorF,
    positions: &[usize],
    targets: &[i32],
) -> Result<Vec<f64>> {
    if logits.rank() != 2 {
        bail!("nll_per_token expects [S, V] logits, got {:?}", logits.shape);
    }
    if positions.len() != targets.len() {
        bail!("positions/targets length mismatch");
    }
    let v = logits.shape[1];
    let mut out = Vec::with_capacity(targets.len());
    for (&pos, &t) in positions.iter().zip(targets) {
        if pos >= logits.shape[0] {
            bail!("position {pos} out of range");
        }
        if (t as usize) >= v || t < 0 {
            bail!("target {t} out of vocab {v}");
        }
        let lp = log_softmax(logits.row(pos));
        out.push(-lp[t as usize] as f64);
    }
    Ok(out)
}

/// PPL from a set of per-token NLLs.
pub fn ppl_from_nll(nll: &[f64]) -> f64 {
    if nll.is_empty() {
        return f64::NAN;
    }
    (nll.iter().sum::<f64>() / nll.len() as f64).exp()
}

/// Sum of option-token log-probabilities (0-shot unnormalized MCQ
/// scoring, Tab. 1): logits row i predicts token i+1.
pub fn option_logprob(
    logits: &TensorF,
    start: usize,
    option_tokens: &[i32],
) -> Result<f64> {
    let mut total = 0.0;
    for (i, &t) in option_tokens.iter().enumerate() {
        let pos = start + i;
        if pos >= logits.shape[0] {
            bail!("option extends past scored window");
        }
        let lp = log_softmax(logits.row(pos));
        total += lp[t as usize] as f64;
    }
    Ok(total)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn logits_2x4() -> TensorF {
        // row 0 strongly predicts token 2; row 1 uniform
        TensorF::new(
            vec![2, 4],
            vec![0.0, 0.0, 10.0, 0.0, 1.0, 1.0, 1.0, 1.0],
        )
        .unwrap()
    }

    #[test]
    fn nll_matches_softmax() {
        let l = logits_2x4();
        let nll = nll_per_token(&l, &[0, 1], &[2, 0]).unwrap();
        assert!(nll[0] < 0.01); // near-certain prediction
        assert!((nll[1] - (4f64).ln()).abs() < 1e-5); // uniform
    }

    #[test]
    fn ppl_of_uniform_is_vocab() {
        let l = TensorF::new(vec![1, 4], vec![0.5; 4]).unwrap();
        let nll = nll_per_token(&l, &[0], &[3]).unwrap();
        assert!((ppl_from_nll(&nll) - 4.0).abs() < 1e-4);
    }

    #[test]
    fn perfect_model_ppl_one() {
        let mut data = vec![-30.0f32; 8];
        data[1] = 30.0; // row 0 predicts token 1
        data[4 + 2] = 30.0; // row 1 predicts token 2
        let l = TensorF::new(vec![2, 4], data).unwrap();
        let nll = nll_per_token(&l, &[0, 1], &[1, 2]).unwrap();
        assert!((ppl_from_nll(&nll) - 1.0).abs() < 1e-4);
    }

    #[test]
    fn option_logprob_sums() {
        let l = logits_2x4();
        let lp = option_logprob(&l, 0, &[2, 0]).unwrap();
        let n = nll_per_token(&l, &[0, 1], &[2, 0]).unwrap();
        assert!((lp + n.iter().sum::<f64>()).abs() < 1e-9);
    }

    #[test]
    fn bounds_checked() {
        let l = logits_2x4();
        assert!(nll_per_token(&l, &[5], &[0]).is_err());
        assert!(nll_per_token(&l, &[0], &[9]).is_err());
        assert!(option_logprob(&l, 1, &[0, 0]).is_err());
    }

    #[test]
    fn empty_nll_is_nan() {
        assert!(ppl_from_nll(&[]).is_nan());
    }
}
