//! Evaluation metrics (App. B.2): deviation-from-dense PPL, top-100 KLD,
//! ROUGE-1/2/L, token F1 / exact match, and MCQ scoring helpers.

pub mod kld;
pub mod ppl;
pub mod rouge;
pub mod text_metrics;

pub use kld::topk_kld;
pub use ppl::{nll_per_token, ppl_from_nll};
pub use rouge::{rouge_l, rouge_n, RougeScores};
pub use text_metrics::{exact_match, normalize_answer, token_f1};
