//! Token-level F1 and exact match (App. B.2.5-6): answers are normalized
//! (lower-case, punctuation and articles stripped) before comparison.

use std::collections::HashMap;

/// Normalize an answer string: lowercase, drop punctuation, drop the
/// articles a/an/the, collapse whitespace.
pub fn normalize_answer(s: &str) -> Vec<String> {
    s.to_lowercase()
        .chars()
        .map(|c| if c.is_alphanumeric() || c.is_whitespace() { c } else { ' ' })
        .collect::<String>()
        .split_whitespace()
        .filter(|w| !matches!(*w, "a" | "an" | "the"))
        .map(|w| w.to_string())
        .collect()
}

/// Exact match after normalization.
pub fn exact_match(pred: &str, reference: &str) -> bool {
    normalize_answer(pred) == normalize_answer(reference)
}

/// Token-level F1 over normalized multisets (App. B.2.6).
pub fn token_f1(pred: &str, reference: &str) -> f64 {
    let p = normalize_answer(pred);
    let r = normalize_answer(reference);
    if p.is_empty() || r.is_empty() {
        return if p == r { 1.0 } else { 0.0 };
    }
    let mut counts: HashMap<&str, usize> = HashMap::new();
    for w in &r {
        *counts.entry(w.as_str()).or_insert(0) += 1;
    }
    let mut common = 0usize;
    for w in &p {
        if let Some(c) = counts.get_mut(w.as_str()) {
            if *c > 0 {
                *c -= 1;
                common += 1;
            }
        }
    }
    if common == 0 {
        return 0.0;
    }
    let precision = common as f64 / p.len() as f64;
    let recall = common as f64 / r.len() as f64;
    2.0 * precision * recall / (precision + recall)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn normalization_rules() {
        assert_eq!(
            normalize_answer("The red Fox!"),
            vec!["red".to_string(), "fox".to_string()]
        );
        assert_eq!(normalize_answer("a an the"), Vec::<String>::new());
    }

    #[test]
    fn em_ignores_case_punct_articles() {
        assert!(exact_match("the Red fox.", "red fox"));
        assert!(!exact_match("blue fox", "red fox"));
    }

    #[test]
    fn f1_perfect_and_zero() {
        assert!((token_f1("red fox", "the red fox") - 1.0).abs() < 1e-12);
        assert_eq!(token_f1("blue dog", "red fox"), 0.0);
    }

    #[test]
    fn f1_partial_overlap() {
        // pred {near, lake}, ref {near, river}: common=1, P=R=0.5
        let f1 = token_f1("near the lake", "near the river");
        assert!((f1 - 0.5).abs() < 1e-12);
    }

    #[test]
    fn f1_multiset_clipping() {
        // repeated predicted tokens don't over-count
        let f1 = token_f1("red red red", "red");
        let p = 1.0 / 3.0;
        let expect = 2.0 * p * 1.0 / (p + 1.0);
        assert!((f1 - expect).abs() < 1e-12);
    }

    #[test]
    fn empty_edge_cases() {
        assert_eq!(token_f1("", ""), 1.0);
        assert_eq!(token_f1("", "x"), 0.0);
        assert_eq!(token_f1("the", "x"), 0.0); // normalizes to empty
    }
}
