//! `cpu-q8`: the int8 weight-quantized CPU backend.
//!
//! This is the second *real* execution backend behind the
//! [`super::ExecBackend`] trait. It keeps the repo's analytically
//! controlled toy-model **head** (grammar logits, closed-form KV rows —
//! shared with [`super::sim`] so the whole cross-executable test corpus
//! pins both backends to one semantic contract) while replacing the
//! compute that GLASS actually accelerates with real quantized kernels:
//!
//! * **FFN data path.** Every logits-emitting token runs a real masked
//!   FFN over the manifest's `w_up`/`w_gate`/`w_down` weights,
//!   quantized per-row to int8 at load time ([`quant::QuantMatrix`]).
//!   The GLASS mask arrives as a kept-row list and masked-out unit
//!   rows are NEVER loaded or multiplied — density 0.3 means ~0.3× the
//!   FFN memory traffic (`ffn_rows_visited`/`ffn_rows_skipped`
//!   counters; poisoned-weight canary below). The FFN output is folded
//!   into the returned logits as a uniform (softmax-invariant) tap, so
//!   the quantized path is load-bearing: a poisoned row read anywhere
//!   surfaces as NaN in the output.
//! * **Importance statistics.** The toy model's neuron-importance head
//!   is materialized as real int8 projection matrices (geometric gain
//!   profile × hash jitter, decode rows carrying the ±Δ drift), so the
//!   `[b, L, m]` f32 statistics tensor is collected from *dequantized
//!   activations of a real quantized GEMV* — same shape, same dtype,
//!   same ℓ2 normalization as the sim backend, which is what lets
//!   `ImportanceMap::merge` and mask refresh run unchanged on both
//!   backends (the quantization seam stays below the GLASS boundary).
//!
//! Everything is integer-accumulated or a pure function of
//! (token, position, layer), so the backend reports
//! `deterministic: true`: fused/step decode agree bitwise, chunked
//! prefill is partition-invariant, and runs reproduce exactly.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use anyhow::{anyhow, bail, Result};

use super::manifest::{ExeSpec, Manifest, ModelSpec};
use super::quant::{self, QuantMatrix, Simd};
use super::sim::{self, SimBackend};
use super::Value;
use crate::tensor::TensorF;
use crate::util::threadpool::ThreadPool;
use crate::util::timer;

/// Input width of the importance-projection GEMVs.
const STAT_D: usize = 32;
/// Jitter amplitude in the importance projections. 0.0625·127 ≈ 8
/// int8 levels, so the jitter survives quantization; bounded so it can
/// never reorder adjacent units of the geometric gain profile.
const STAT_JIT: f32 = 0.0625;
/// Amplitude of the uniform FFN logit tap. Softmax-invariant by
/// construction (same value added to every logit of a row) and far
/// below the cross-program comparison tolerance, but NaN-transparent.
const DELTA_SCALE: f32 = 1e-4;

const SALT_STAT_W: u64 = 0x9101;
const SALT_STAT_X: u64 = 0x9102;
const KIND_PROMPT: u64 = 0;
const KIND_DEC: u64 = 1;

/// One transformer layer's quantized FFN projections. `up`/`gate` are
/// stored transposed (`[m, d]`) so each FFN unit is one contiguous,
/// individually skippable row; `down` is `[m, d]` natively.
struct FfnLayerQ8 {
    up: QuantMatrix,
    gate: QuantMatrix,
    down: QuantMatrix,
}

/// The int8 CPU backend. Immutable after construction (canary helpers
/// aside) and `Send + Sync`; safe to share across shard threads.
pub struct CpuQ8Backend {
    sim: SimBackend,
    spec: ModelSpec,
    simd: Simd,
    embed: QuantMatrix,
    layers: Vec<FfnLayerQ8>,
    /// Importance projections: per layer, `[m, STAT_D]`.
    stat_prompt: Vec<QuantMatrix>,
    stat_dec: Vec<QuantMatrix>,
    /// Every unit id, reused for maskless (dense) executables.
    dense_rows: Vec<usize>,
    /// Lazily created worker pool for large masked GEMVs. `None` both
    /// before first use and while a call has it checked out — a
    /// concurrent caller just runs the sequential kernel (identical
    /// result, see `quant`).
    pool: Mutex<Option<ThreadPool>>,
    rows_visited: AtomicU64,
    rows_skipped: AtomicU64,
}

impl CpuQ8Backend {
    /// Quantize the host weights into the int8 store. `param_host`
    /// must be in manifest order (as produced by `Runtime` loading).
    pub fn new(
        manifest: &Manifest,
        param_host: &[Vec<f32>],
    ) -> Result<CpuQ8Backend> {
        if param_host.len() != manifest.params.len() {
            bail!(
                "cpu-q8: {} host params for {} manifest entries",
                param_host.len(),
                manifest.params.len()
            );
        }
        let spec = manifest.model.clone();
        let find = |name: &str| -> Result<&[f32]> {
            manifest
                .params
                .iter()
                .position(|p| p.name == name)
                .map(|i| param_host[i].as_slice())
                .ok_or_else(|| {
                    anyhow!("cpu-q8: param '{name}' missing from manifest")
                })
        };
        let embed =
            QuantMatrix::from_rows(spec.vocab, spec.d_model, find("embed")?)?;
        let mut layers = Vec::with_capacity(spec.n_layers);
        for l in 0..spec.n_layers {
            layers.push(FfnLayerQ8 {
                up: QuantMatrix::from_columns(
                    spec.d_model,
                    spec.ffn_m,
                    find(&format!("layer{l}.w_up"))?,
                )?,
                gate: QuantMatrix::from_columns(
                    spec.d_model,
                    spec.ffn_m,
                    find(&format!("layer{l}.w_gate"))?,
                )?,
                down: QuantMatrix::from_rows(
                    spec.ffn_m,
                    spec.d_model,
                    find(&format!("layer{l}.w_down"))?,
                )?,
            });
        }
        let sim = SimBackend::new(spec.clone());
        let stat_prompt =
            build_stat_mats(&sim.gain, KIND_PROMPT, spec.n_layers)?;
        let stat_dec = build_stat_mats(&sim.w_dec, KIND_DEC, spec.n_layers)?;
        let dense_rows: Vec<usize> = (0..spec.ffn_m).collect();
        Ok(CpuQ8Backend {
            sim,
            spec,
            simd: quant::detect(),
            embed,
            layers,
            stat_prompt,
            stat_dec,
            dense_rows,
            pool: Mutex::new(None),
            rows_visited: AtomicU64::new(0),
            rows_skipped: AtomicU64::new(0),
        })
    }

    /// FFN unit rows actually loaded since construction.
    pub fn ffn_rows_visited(&self) -> u64 {
        self.rows_visited.load(Ordering::Relaxed)
    }

    /// FFN unit rows skipped (masked out, never loaded).
    pub fn ffn_rows_skipped(&self) -> u64 {
        self.rows_skipped.load(Ordering::Relaxed)
    }

    /// The SIMD kernel this host selected.
    pub fn simd(&self) -> Simd {
        self.simd
    }

    /// Quantized FFN + embed weight bytes resident in this backend.
    pub fn quantized_bytes(&self) -> usize {
        self.embed.weight_bytes()
            + self
                .layers
                .iter()
                .map(|l| {
                    l.up.weight_bytes()
                        + l.gate.weight_bytes()
                        + l.down.weight_bytes()
                })
                .sum::<usize>()
    }

    /// Canary helper: poison FFN unit rows of one layer across all
    /// three projections, so any read of them propagates NaN into the
    /// call output. Used by the poisoned-weight canary test to prove
    /// masked-out rows are never touched.
    pub fn poison_ffn_rows(&mut self, layer: usize, rows: &[usize]) {
        let l = &mut self.layers[layer];
        for &j in rows {
            l.up.poison_row(j);
            l.gate.poison_row(j);
            l.down.poison_row(j);
        }
    }

    // ---------------------------------------------------- real compute

    /// One importance-statistics vector: |dequantized GEMV output| of
    /// the layer's int8 projection, ℓ2-normalized (the sim contract).
    fn stat_vec(
        &self,
        kind: u64,
        t: i32,
        p: i32,
        l: usize,
    ) -> Vec<f64> {
        let mut x = [0.0f32; STAT_D];
        x[0] = 1.0;
        for (c, xc) in x.iter_mut().enumerate().skip(1) {
            let h = sim::h01(&[
                SALT_STAT_X,
                kind,
                t as u64,
                p as u64,
                l as u64,
                c as u64,
            ]);
            *xc = if h < 0.5 { -STAT_JIT } else { STAT_JIT };
        }
        let (xq, xs) = quant::quantize_row(&x);
        let w = if kind == KIND_PROMPT {
            &self.stat_prompt[l]
        } else {
            &self.stat_dec[l]
        };
        let mut out = vec![0.0f32; self.spec.ffn_m];
        quant::dense_gemv(self.simd, w, &xq, xs, &mut out);
        let mut v: Vec<f64> = out.iter().map(|a| a.abs() as f64).collect();
        sim::l2_normalize(&mut v);
        v
    }

    /// Prompt-time statistics for one (token, layer) — position-free so
    /// chunked prefill stays partition-invariant.
    fn prompt_stats(&self, t: i32, l: usize) -> Vec<f64> {
        self.stat_vec(KIND_PROMPT, t, 0, l)
    }

    /// Decode-time statistics (carrying the ±Δ drift profile).
    fn dec_stats(&self, t: i32, p: i32, l: usize) -> Vec<f64> {
        self.stat_vec(KIND_DEC, t, p, l)
    }

    /// Run the masked FFN stack for token `t` with the GLASS kept-row
    /// lists and fold the output into a single softmax-invariant logit
    /// tap. Masked-out rows are never loaded (counted in
    /// `ffn_rows_skipped`).
    fn ffn_delta(&self, t: i32, kept: &[Vec<usize>]) -> f32 {
        let tok = (t.max(0) as usize).min(self.spec.vocab - 1);
        let x = self.embed.dequantize_row(tok);
        let (xq, xs) = quant::quantize_row(&x);
        let d = self.spec.d_model;
        let mut y = vec![0.0f32; d];
        let mut visited = 0u64;
        let mut skipped = 0u64;
        for (l, layer) in self.layers.iter().enumerate() {
            let rows = kept.get(l).map(|v| v.as_slice()).unwrap_or(&[]);
            if rows.len() * d >= quant::POOL_MIN_MACS {
                self.ffn_layer_pooled(layer, &xq, xs, rows, &mut y);
            } else {
                quant::ffn_forward_masked(
                    self.simd, &layer.up, &layer.gate, &layer.down, &xq, xs,
                    rows, &mut y, None,
                );
            }
            visited += rows.len() as u64;
            skipped += (self.spec.ffn_m - rows.len()) as u64;
        }
        // Relaxed: monotonic telemetry counters — readers only ever
        // compare totals after the calls that bumped them returned, so
        // no ordering with other memory is required
        self.rows_visited.fetch_add(visited, Ordering::Relaxed);
        self.rows_skipped.fetch_add(skipped, Ordering::Relaxed);
        let mean = y.iter().map(|&v| v as f64).sum::<f64>()
            / (d as f64 * self.layers.len().max(1) as f64);
        DELTA_SCALE * mean.tanh() as f32
    }

    /// Large-model path: up/gate GEMVs on the worker pool (bit-identical
    /// to the sequential kernel), down-projection accumulated inline.
    fn ffn_layer_pooled(
        &self,
        layer: &FfnLayerQ8,
        xq: &[i8],
        xs: f32,
        rows: &[usize],
        y: &mut [f32],
    ) {
        // check the pool out of the slot; a concurrent call (or a
        // poisoned lock) just runs sequentially — same bits either way
        let pool = self.pool.lock().ok().and_then(|mut g| g.take());
        let pool = match pool {
            Some(p) => p,
            None => {
                let n = std::thread::available_parallelism()
                    .map(|n| n.get())
                    .unwrap_or(1)
                    .clamp(1, 8);
                ThreadPool::new(n)
            }
        };
        let m = self.spec.ffn_m;
        let mut up_out = vec![0.0f32; m];
        let mut gate_out = vec![0.0f32; m];
        quant::masked_gemv_pooled(
            self.simd, &layer.up, xq, xs, rows, &mut up_out, &pool, 8,
        );
        quant::masked_gemv_pooled(
            self.simd, &layer.gate, xq, xs, rows, &mut gate_out, &pool, 8,
        );
        if let Ok(mut g) = self.pool.lock() {
            // put the pool back; if a racing call created another one,
            // the extra pool drops (joining its idle workers)
            g.get_or_insert(pool);
        }
        for &j in rows {
            let a = quant::silu(gate_out[j]) * up_out[j];
            let ds = layer.down.scale(j);
            let drow = layer.down.row(j);
            let n = y.len().min(drow.len());
            for c in 0..n {
                y[c] += a * (drow[c] as f32 * ds);
            }
        }
    }

    // ------------------------------------------------- post-processing
    //
    // The closed-form head (logits strengths, KV rows, trajectories)
    // comes from the shared sim model; these passes then (a) fold the
    // real masked-FFN tap into the logits and (b) REPLACE the
    // statistics outputs with the quantized importance activations.

    fn post_prefill(
        &self,
        b: usize,
        operands: &[Value],
        out: &mut [Value],
        chunked: bool,
    ) -> Result<()> {
        let spec = &self.spec;
        let tokens = operands[0].as_i32()?;
        let lens = operands[1].as_i32()?;
        let s_pre = spec.prefill_len;
        let kept_dense: Vec<Vec<usize>> =
            vec![self.dense_rows.clone(); spec.n_layers];
        let mut stats = vec![0.0f32; b * spec.n_layers * spec.ffn_m];
        let mut deltas = vec![0.0f32; b];
        for slot in 0..b {
            let len = if chunked {
                (lens.data[slot].max(0) as usize).min(s_pre)
            } else {
                (lens.data[slot].max(1) as usize).min(s_pre)
            };
            if len == 0 {
                continue; // idle chunk slot: zero logits, zero stats
            }
            let toks = &tokens.data[slot * s_pre..slot * s_pre + len];
            deltas[slot] = self.ffn_delta(toks[len - 1], &kept_dense);
            for l in 0..spec.n_layers {
                let base = (slot * spec.n_layers + l) * spec.ffn_m;
                for &t in toks {
                    let st = self.prompt_stats(t, l);
                    for j in 0..spec.ffn_m {
                        stats[base + j] += (st[j] / len as f64) as f32;
                    }
                }
            }
        }
        add_logit_tap(&mut out[0], spec.vocab, &deltas)?;
        out[3] = Value::F32(TensorF::new(
            vec![b, spec.n_layers, spec.ffn_m],
            stats,
        )?);
        Ok(())
    }

    fn post_decode(
        &self,
        b: usize,
        operands: &[Value],
        out: &mut [Value],
        gathered: bool,
    ) -> Result<()> {
        let spec = &self.spec;
        let tokens = operands[0].as_i32()?;
        let pos = operands[1].as_i32()?;
        let mut stats = vec![0.0f32; b * spec.n_layers * spec.ffn_m];
        let mut deltas = vec![0.0f32; b];
        for slot in 0..b {
            let kept = if gathered {
                self.sim.kept_from_idx(operands[4].as_i32()?, slot)
            } else {
                self.sim.kept_from_mask(operands[4].as_f32()?, slot)
            };
            let t = tokens.data[slot];
            let p = pos.data[slot];
            deltas[slot] = self.ffn_delta(t, &kept);
            for l in 0..spec.n_layers {
                let st = self.dec_stats(t, p, l);
                let base = (slot * spec.n_layers + l) * spec.ffn_m;
                for j in 0..spec.ffn_m {
                    stats[base + j] = st[j] as f32;
                }
            }
        }
        add_logit_tap(&mut out[0], spec.vocab, &deltas)?;
        out[3] = Value::F32(TensorF::new(
            vec![b, spec.n_layers, spec.ffn_m],
            stats,
        )?);
        Ok(())
    }

    fn post_score(
        &self,
        b: usize,
        operands: &[Value],
        out: &mut [Value],
    ) -> Result<()> {
        let spec = &self.spec;
        let tokens = operands[0].as_i32()?;
        let weights = operands[1].as_f32()?;
        let s_len = spec.score_len;
        let mut stats = vec![0.0f32; b * spec.n_layers * spec.ffn_m];
        for slot in 0..b {
            let mut w_total = 0.0f64;
            let mut acc = vec![vec![0.0f64; spec.ffn_m]; spec.n_layers];
            for p in 0..s_len {
                let t = tokens.data[slot * s_len + p];
                let w = weights.data[slot * s_len + p] as f64;
                if w > 0.0 {
                    w_total += w;
                    for l in 0..spec.n_layers {
                        let st = self.dec_stats(t, p as i32, l);
                        for j in 0..spec.ffn_m {
                            acc[l][j] += w * st[j];
                        }
                    }
                }
            }
            if w_total > 0.0 {
                for l in 0..spec.n_layers {
                    let base = (slot * spec.n_layers + l) * spec.ffn_m;
                    for j in 0..spec.ffn_m {
                        stats[base + j] = (acc[l][j] / w_total) as f32;
                    }
                }
            }
        }
        out[1] = Value::F32(TensorF::new(
            vec![b, spec.n_layers, spec.ffn_m],
            stats,
        )?);
        Ok(())
    }

    fn post_generate(
        &self,
        b: usize,
        operands: &[Value],
        out: &mut [Value],
    ) -> Result<()> {
        let spec = &self.spec;
        let lens = operands[1].as_i32()?;
        let gen_toks = out[0].as_i32()?.clone();
        let n = spec.gen_len;
        let mut stats = vec![0.0f32; b * spec.n_layers * spec.ffn_m];
        for slot in 0..b {
            let len =
                (lens.data[slot].max(1) as usize).min(spec.prefill_len);
            for i in 0..n {
                let tok = gen_toks.data[slot * n + i];
                let p = (len + i) as i32;
                for l in 0..spec.n_layers {
                    let st = self.dec_stats(tok, p, l);
                    let base = (slot * spec.n_layers + l) * spec.ffn_m;
                    for j in 0..spec.ffn_m {
                        stats[base + j] += (st[j] / n as f64) as f32;
                    }
                }
            }
        }
        out[2] = Value::F32(TensorF::new(
            vec![b, spec.n_layers, spec.ffn_m],
            stats,
        )?);
        Ok(())
    }
}

/// Add each slot's FFN tap uniformly to its logits row (softmax- and
/// argmax-invariant; NaN-transparent for the canary).
fn add_logit_tap(
    logits: &mut Value,
    vocab: usize,
    deltas: &[f32],
) -> Result<()> {
    match logits {
        Value::F32(t) => {
            for (slot, &d) in deltas.iter().enumerate() {
                if d == 0.0 {
                    continue; // idle chunk slots keep their zero rows
                }
                for v in &mut t.data[slot * vocab..(slot + 1) * vocab] {
                    *v += d;
                }
            }
            Ok(())
        }
        Value::I32(_) => bail!("logits output must be f32"),
    }
}

/// Build the per-layer importance projections: row `j` is the unit's
/// base weight (geometric gain, or the drifted decode profile) times
/// `[1, ±JIT, ±JIT, ...]` — after the GEMV the |activation| profile is
/// `base[j]·(1 + bounded jitter)`, the same family the sim model uses.
/// The jitter bound (≈ ±12% after quantization) is strictly below the
/// 30% gap between adjacent gain-profile units, so quantization can
/// never reorder importance ranks.
fn build_stat_mats(
    base: &[f64],
    kind: u64,
    n_layers: usize,
) -> Result<Vec<QuantMatrix>> {
    let m = base.len();
    let mut mats = Vec::with_capacity(n_layers);
    for l in 0..n_layers {
        let mut rows = vec![0.0f32; m * STAT_D];
        for j in 0..m {
            let w = base[j] as f32;
            rows[j * STAT_D] = w;
            for c in 1..STAT_D {
                let h = sim::h01(&[
                    SALT_STAT_W,
                    kind,
                    l as u64,
                    j as u64,
                    c as u64,
                ]);
                let s = if h < 0.5 { -1.0 } else { 1.0 };
                rows[j * STAT_D + c] = w * STAT_JIT * s;
            }
        }
        mats.push(QuantMatrix::from_rows(m, STAT_D, &rows)?);
    }
    Ok(mats)
}

impl super::ExecBackend for CpuQ8Backend {
    fn name(&self) -> &'static str {
        "cpu-q8"
    }

    fn capabilities(&self) -> super::Capabilities {
        super::Capabilities {
            native_masked_ffn: true,
            chunked_prefill: true,
            needs_warmup: false,
            deterministic: true,
        }
    }

    fn compile(&self, manifest: &Manifest, name: &str) -> Result<()> {
        manifest.exe(name).map(|_| ())
    }

    fn call(
        &self,
        _manifest: &Manifest,
        spec: &ExeSpec,
        operands: &[Value],
    ) -> Result<Vec<Value>> {
        let _t = timer::global().start("runtime.execute");
        let (kind, b) = sim::parse_exe_name(&spec.name).ok_or_else(|| {
            anyhow!("cpu-q8 backend: bad exe name '{}'", spec.name)
        })?;
        let mut out = SimBackend::call(&self.sim, &spec.name, operands)?;
        match kind {
            "prefill" => self.post_prefill(b, operands, &mut out, false)?,
            "prefill_chunk" => {
                self.post_prefill(b, operands, &mut out, true)?
            }
            "decode" => self.post_decode(b, operands, &mut out, false)?,
            "decode_topk" => {
                self.post_decode(b, operands, &mut out, true)?
            }
            "score" => self.post_score(b, operands, &mut out)?,
            "generate" => self.post_generate(b, operands, &mut out)?,
            _ => {}
        }
        Ok(out)
    }

    fn prior(&self, name: &str) -> Option<Result<Vec<Vec<f32>>>> {
        // the global priors describe the same toy model; sharing the
        // sim's closed-form priors keeps λ rank fusion backend-agnostic
        Some(self.sim.prior(name))
    }
}

#[cfg(test)]
mod tests {
    use super::super::ExecBackend;
    use super::*;
    use crate::tensor::TensorI;

    fn backend() -> CpuQ8Backend {
        let manifest = sim::synthetic_manifest();
        let params: Vec<Vec<f32>> = manifest
            .params
            .iter()
            .map(|p| SimBackend::param_values(&p.name, p.numel))
            .collect();
        CpuQ8Backend::new(&manifest, &params).unwrap()
    }

    fn decode_operands(
        spec: &ModelSpec,
        kept: &[usize],
    ) -> Vec<Value> {
        let kv_shape = [
            spec.n_layers,
            1,
            spec.n_heads,
            spec.max_seq,
            spec.head_dim,
        ];
        let mut mask = vec![0.0f32; spec.n_layers * spec.ffn_m];
        for l in 0..spec.n_layers {
            for &j in kept {
                mask[l * spec.ffn_m + j] = 1.0;
            }
        }
        vec![
            Value::I32(TensorI::new(vec![1], vec![101]).unwrap()),
            Value::I32(TensorI::new(vec![1], vec![9]).unwrap()),
            Value::F32(TensorF::zeros(&kv_shape)),
            Value::F32(TensorF::zeros(&kv_shape)),
            Value::F32(
                TensorF::new(
                    vec![1, spec.n_layers, spec.ffn_m],
                    mask,
                )
                .unwrap(),
            ),
        ]
    }

    fn call(
        be: &CpuQ8Backend,
        name: &str,
        operands: &[Value],
    ) -> Vec<Value> {
        let manifest = sim::synthetic_manifest();
        let spec = manifest.exe(name).unwrap().clone();
        ExecBackend::call(be, &manifest, &spec, operands).unwrap()
    }

    #[test]
    fn poisoned_weight_canary_masked_rows_never_read() {
        // THE acceptance-criteria canary: poison every masked-out FFN
        // row; if the backend ever loaded one, NaN would reach the
        // logits. Output must be bit-identical to the clean backend.
        let clean = backend();
        let spec = clean.spec.clone();
        let density_03 = (spec.ffn_m as f64 * 0.3).round() as usize;
        let kept: Vec<usize> = (0..density_03).collect();
        let masked_out: Vec<usize> =
            (density_03..spec.ffn_m).collect();
        let mut poisoned = backend();
        for l in 0..spec.n_layers {
            poisoned.poison_ffn_rows(l, &masked_out);
        }
        let ops = decode_operands(&spec, &kept);
        let a = call(&clean, "decode_b1", &ops);
        let b = call(&poisoned, "decode_b1", &ops);
        let logits_a = a[0].as_f32().unwrap();
        let logits_b = b[0].as_f32().unwrap();
        assert!(
            logits_b.data.iter().all(|v| v.is_finite()),
            "poisoned masked-out rows leaked into the logits"
        );
        assert_eq!(
            logits_a.data.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            logits_b.data.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            "masked decode must not depend on masked-out row contents"
        );
        // control: a DENSE decode on the poisoned backend must read the
        // poisoned rows and surface NaN — proving the canary has teeth
        let dense: Vec<usize> = (0..spec.ffn_m).collect();
        let dense_ops = decode_operands(&spec, &dense);
        let c = call(&poisoned, "decode_b1", &dense_ops);
        assert!(
            c[0].as_f32().unwrap().data.iter().any(|v| v.is_nan()),
            "canary is dead: dense decode ignored poisoned rows"
        );
    }

    #[test]
    fn density_translates_into_row_traffic() {
        let be = backend();
        let spec = be.spec.clone();
        let kept: Vec<usize> =
            (0..(spec.ffn_m as f64 * 0.3).round() as usize).collect();
        let ops = decode_operands(&spec, &kept);
        let before = (be.ffn_rows_visited(), be.ffn_rows_skipped());
        call(&be, "decode_b1", &ops);
        let visited = be.ffn_rows_visited() - before.0;
        let skipped = be.ffn_rows_skipped() - before.1;
        let total = (visited + skipped) as f64;
        let ratio = visited as f64 / total;
        assert!(
            (ratio - 0.3).abs() < 0.05,
            "density 0.3 should mean ~0.3x row traffic, got {ratio}"
        );
    }

    #[test]
    fn decode_is_deterministic_across_backend_instances() {
        let a = backend();
        let b = backend();
        let spec = a.spec.clone();
        let kept: Vec<usize> = (0..spec.ffn_m / 2).collect();
        let ops = decode_operands(&spec, &kept);
        let ra = call(&a, "decode_b1", &ops);
        let rb = call(&b, "decode_b1", &ops);
        for (va, vb) in ra.iter().zip(&rb) {
            if let (Ok(ta), Ok(tb)) = (va.as_f32(), vb.as_f32()) {
                assert_eq!(
                    ta.data.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                    tb.data.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
                );
            }
        }
    }

    #[test]
    fn single_frame_chunk_matches_monolithic_prefill_bitwise() {
        let be = backend();
        let spec = be.spec.clone();
        let s = spec.prefill_len;
        let toks = [97i32, 98, 99, 100, 101, 32, 97];
        let mut frame = vec![spec.pad_id; s];
        frame[..toks.len()].copy_from_slice(&toks);
        let tokens = TensorI::new(vec![1, s], frame).unwrap();
        let lens =
            TensorI::new(vec![1], vec![toks.len() as i32]).unwrap();
        let mono = call(
            &be,
            "prefill_b1",
            &[Value::I32(tokens.clone()), Value::I32(lens.clone())],
        );
        let kv_shape = [
            spec.n_layers,
            1,
            spec.n_heads,
            spec.max_seq,
            spec.head_dim,
        ];
        let chunk = call(
            &be,
            "prefill_chunk_b1",
            &[
                Value::I32(tokens),
                Value::I32(lens),
                Value::I32(TensorI::new(vec![1], vec![0]).unwrap()),
                Value::F32(TensorF::zeros(&kv_shape)),
                Value::F32(TensorF::zeros(&kv_shape)),
            ],
        );
        let bits = |v: &Value| -> Vec<u32> {
            v.as_f32()
                .unwrap()
                .data
                .iter()
                .map(|x| x.to_bits())
                .collect()
        };
        assert_eq!(bits(&mono[0]), bits(&chunk[0]), "logits");
        assert_eq!(bits(&mono[3]), bits(&chunk[3]), "stats");
    }

    #[test]
    fn stats_have_sim_shape_and_geometric_ordering() {
        // the quantization seam: statistics come from real dequantized
        // GEMV activations but keep the sim tensor contract — same
        // shape/dtype, ℓ2-normalized, importance ordered by the
        // geometric gain profile (so GLASS top-k picks the same units)
        let be = backend();
        let spec = be.spec.clone();
        let s = spec.prefill_len;
        let mut frame = vec![spec.pad_id; s];
        frame[0] = 105;
        let ops = [
            Value::I32(TensorI::new(vec![1, s], frame).unwrap()),
            Value::I32(TensorI::new(vec![1], vec![1]).unwrap()),
        ];
        let out = call(&be, "prefill_b1", &ops);
        let stats = out[3].as_f32().unwrap();
        assert_eq!(
            stats.shape,
            vec![1, spec.n_layers, spec.ffn_m]
        );
        for l in 0..spec.n_layers {
            let row =
                &stats.data[l * spec.ffn_m..(l + 1) * spec.ffn_m];
            let norm: f32 =
                row.iter().map(|x| x * x).sum::<f32>().sqrt();
            assert!((norm - 1.0).abs() < 1e-3, "layer {l} norm {norm}");
            for j in 1..spec.ffn_m {
                assert!(
                    row[j - 1] > row[j],
                    "layer {l}: unit {} not above unit {j}",
                    j - 1
                );
            }
        }
    }
}
