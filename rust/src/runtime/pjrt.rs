//! PJRT backend: load HLO-text artifacts, compile once, execute from the
//! request path with device-resident model weights. Only compiled with
//! `--features pjrt` (the offline default build uses [`super::sim`]).
//!
//! Flow (see /opt/xla-example/load_hlo and aot_recipe):
//!   `PjRtClient::cpu()` → `HloModuleProto::from_text_file` →
//!   `XlaComputation::from_proto` → `client.compile` → `execute_b`.
//!
//! Model parameters are uploaded to the device **once** per runtime and
//! passed as the leading arguments of every call (`execute_b`), so the
//! per-step host↔device traffic is only the operands (tokens, masks, KV).
//! Outputs come back as one tuple literal (xla_extension 0.5.1 does not
//! untuple results device-side) and are decomposed into host tensors.

use std::collections::HashMap;
use std::sync::Mutex;

use anyhow::{bail, Context, Result};

use super::manifest::{DType, ExeSpec, Manifest};
use super::Value;
use crate::tensor::{TensorF, TensorI};
use crate::util::timer;

/// PJRT-side state: client, device-resident weights, compiled programs.
///
/// The `xla` crate's wrappers hold non-atomically-refcounted handles
/// (`Rc`) onto the C++ client, so they are neither `Send` nor `Sync`.
/// The underlying PJRT C++ objects are safe to use from multiple threads
/// *sequentially*; we enforce that by funneling every PJRT touch through
/// the `Mutex<PjrtState>` below, which makes the `unsafe impl Send` sound
/// in practice (no concurrent access, no cross-thread Rc clone races —
/// all clones happen under the lock).
struct PjrtState {
    client: xla::PjRtClient,
    /// Model parameters uploaded once, in manifest order.
    param_bufs: Vec<xla::PjRtBuffer>,
    exes: HashMap<String, (ExeSpec, xla::PjRtLoadedExecutable)>,
}

// SAFETY: the `Rc` handles inside are never cloned or dropped outside
// the owning `Mutex<PjrtState>` (see the struct docs above), so no two
// threads ever touch the non-atomic refcounts concurrently.
unsafe impl Send for PjrtState {}

pub struct PjrtBackend {
    state: Mutex<PjrtState>,
}

impl PjrtBackend {
    /// Create the client and upload the host weights once. Takes the
    /// manifest inventory + host tensors by reference — no second host
    /// copy of the model is materialized.
    pub fn load(
        params: &[super::manifest::ParamSpec],
        param_host: &[Vec<f32>],
    ) -> Result<PjrtBackend> {
        let client =
            xla::PjRtClient::cpu().map_err(|e| {
                anyhow::anyhow!("creating PJRT CPU client: {e:?}")
            })?;
        let mut param_bufs = Vec::with_capacity(param_host.len());
        for (spec, floats) in params.iter().zip(param_host) {
            let buf = client
                .buffer_from_host_buffer(floats, &spec.shape, None)
                .map_err(|e| {
                    anyhow::anyhow!("uploading param {}: {e:?}", spec.name)
                })
                .context("uploading model weights")?;
            param_bufs.push(buf);
        }
        Ok(PjrtBackend {
            state: Mutex::new(PjrtState {
                client,
                param_bufs,
                exes: HashMap::new(),
            }),
        })
    }

    /// Compile (and cache) an executable by manifest name.
    pub fn compile(&self, manifest: &Manifest, name: &str) -> Result<()> {
        let mut st = self.state.lock().unwrap();
        compile_locked(&mut st, manifest, name)
    }

    /// Execute by name with operands in manifest order (shapes already
    /// validated by the runtime).
    pub fn call(
        &self,
        manifest: &Manifest,
        spec: &ExeSpec,
        operands: &[Value],
    ) -> Result<Vec<Value>> {
        let mut st = self.state.lock().unwrap();
        compile_locked(&mut st, manifest, &spec.name)?;
        let st = &*st;
        let (spec, exe) = st.exes.get(&spec.name).expect("just compiled");

        let mut inputs: Vec<&xla::PjRtBuffer> =
            st.param_bufs.iter().collect();
        let mut operand_bufs = Vec::with_capacity(operands.len());
        {
            let _t = timer::global().start("runtime.upload");
            for v in operands {
                let buf = match v {
                    Value::F32(t) => st.client.buffer_from_host_buffer(
                        &t.data,
                        &t.shape,
                        None,
                    ),
                    Value::I32(t) => st.client.buffer_from_host_buffer(
                        &t.data,
                        &t.shape,
                        None,
                    ),
                }
                .map_err(|e| anyhow::anyhow!("upload operand: {e:?}"))?;
                operand_bufs.push(buf);
            }
        }
        inputs.extend(operand_bufs.iter());

        let out_bufs = {
            let _t = timer::global().start("runtime.execute");
            exe.execute_b(&inputs)
                .map_err(|e| anyhow::anyhow!("execute {}: {e:?}", spec.name))?
        };
        let _t_dl = timer::global().start("runtime.download");
        let tuple = out_bufs[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("fetch result: {e:?}"))?;
        let parts = tuple
            .to_tuple()
            .map_err(|e| anyhow::anyhow!("untuple result: {e:?}"))?;
        if parts.len() != spec.outputs.len() {
            bail!(
                "exe {}: manifest lists {} outputs, program returned {}",
                spec.name,
                spec.outputs.len(),
                parts.len()
            );
        }
        let mut out = Vec::with_capacity(parts.len());
        for (io, lit) in spec.outputs.iter().zip(parts) {
            let v = match io.dtype {
                DType::F32 => {
                    let data = lit
                        .to_vec::<f32>()
                        .map_err(|e| anyhow::anyhow!("to_vec f32: {e:?}"))?;
                    Value::F32(TensorF::new(io.shape.clone(), data)?)
                }
                DType::I32 => {
                    let data = lit
                        .to_vec::<i32>()
                        .map_err(|e| anyhow::anyhow!("to_vec i32: {e:?}"))?;
                    Value::I32(TensorI::new(io.shape.clone(), data)?)
                }
            };
            out.push(v);
        }
        Ok(out)
    }
}

impl super::ExecBackend for PjrtBackend {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn capabilities(&self) -> super::Capabilities {
        super::Capabilities {
            native_masked_ffn: false,
            chunked_prefill: true,
            // distinct XLA programs reorder float math: fused vs step
            // paths agree only to tolerance, never bitwise
            deterministic: false,
            needs_warmup: true,
        }
    }

    fn compile(&self, manifest: &Manifest, name: &str) -> Result<()> {
        PjrtBackend::compile(self, manifest, name)
    }

    fn call(
        &self,
        manifest: &Manifest,
        spec: &ExeSpec,
        operands: &[Value],
    ) -> Result<Vec<Value>> {
        PjrtBackend::call(self, manifest, spec, operands)
    }
}

fn compile_locked(
    st: &mut PjrtState,
    manifest: &Manifest,
    name: &str,
) -> Result<()> {
    if st.exes.contains_key(name) {
        return Ok(());
    }
    let spec = manifest.exe(name)?.clone();
    let path = manifest.dir.join(&spec.file);
    let _t = timer::global().start("runtime.compile");
    let proto = xla::HloModuleProto::from_text_file(&path)
        .map_err(|e| anyhow::anyhow!("parse {}: {e:?}", path.display()))?;
    let comp = xla::XlaComputation::from_proto(&proto);
    let exe = st
        .client
        .compile(&comp)
        .map_err(|e| anyhow::anyhow!("compile {name}: {e:?}"))?;
    st.exes.insert(name.to_string(), (spec, exe));
    crate::info!("compiled executable '{name}'");
    Ok(())
}
