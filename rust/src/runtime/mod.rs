//! PJRT runtime: load HLO-text artifacts, compile once, execute from the
//! request path with device-resident model weights.
//!
//! Flow (see /opt/xla-example/load_hlo and aot_recipe):
//!   `PjRtClient::cpu()` → `HloModuleProto::from_text_file` →
//!   `XlaComputation::from_proto` → `client.compile` → `execute_b`.
//!
//! Model parameters are uploaded to the device **once** per runtime and
//! passed as the leading arguments of every call (`execute_b`), so the
//! per-step host↔device traffic is only the operands (tokens, masks, KV).
//! Outputs come back as one tuple literal (xla_extension 0.5.1 does not
//! untuple results device-side) and are decomposed into host tensors.

pub mod manifest;

pub use manifest::{DType, ExeSpec, IoSpec, Manifest, ModelSpec, ParamSpec};

use std::collections::HashMap;
use std::path::Path;
use std::sync::Mutex;

use anyhow::{bail, Context, Result};

use crate::tensor::{TensorF, TensorI};
use crate::util::timer;

/// A host-side value crossing the runtime boundary.
#[derive(Debug, Clone)]
pub enum Value {
    F32(TensorF),
    I32(TensorI),
}

impl Value {
    pub fn shape(&self) -> &[usize] {
        match self {
            Value::F32(t) => &t.shape,
            Value::I32(t) => &t.shape,
        }
    }

    pub fn dtype(&self) -> DType {
        match self {
            Value::F32(_) => DType::F32,
            Value::I32(_) => DType::I32,
        }
    }

    pub fn as_f32(&self) -> Result<&TensorF> {
        match self {
            Value::F32(t) => Ok(t),
            Value::I32(_) => bail!("expected f32 value"),
        }
    }

    pub fn as_i32(&self) -> Result<&TensorI> {
        match self {
            Value::I32(t) => Ok(t),
            Value::F32(_) => bail!("expected i32 value"),
        }
    }

    pub fn into_f32(self) -> Result<TensorF> {
        match self {
            Value::F32(t) => Ok(t),
            Value::I32(_) => bail!("expected f32 value"),
        }
    }

    pub fn into_i32(self) -> Result<TensorI> {
        match self {
            Value::I32(t) => Ok(t),
            Value::F32(_) => bail!("expected i32 value"),
        }
    }
}

/// PJRT-side state: client, device-resident weights, compiled programs.
///
/// The `xla` crate's wrappers hold non-atomically-refcounted handles
/// (`Rc`) onto the C++ client, so they are neither `Send` nor `Sync`.
/// The underlying PJRT C++ objects are safe to use from multiple threads
/// *sequentially*; we enforce that by funneling every PJRT touch through
/// the `Mutex<PjrtState>` below, which makes the `unsafe impl Send` sound
/// in practice (no concurrent access, no cross-thread Rc clone races —
/// all clones happen under the lock).
struct PjrtState {
    client: xla::PjRtClient,
    /// Model parameters uploaded once, in manifest order.
    param_bufs: Vec<xla::PjRtBuffer>,
    exes: HashMap<String, (ExeSpec, xla::PjRtLoadedExecutable)>,
}

unsafe impl Send for PjrtState {}

/// The runtime: the manifest, the serialized PJRT state, and host copies
/// of the weights (for the memory simulator and diagnostics).
pub struct Runtime {
    pub manifest: Manifest,
    state: Mutex<PjrtState>,
    /// Raw host copy of the weights (memsim + weight inspection need it).
    pub param_host: Vec<Vec<f32>>,
}

impl Runtime {
    /// Load the artifact bundle at `dir`.
    pub fn load(dir: &Path) -> Result<Runtime> {
        let manifest = Manifest::load(dir)?;
        let client =
            xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        let raw = std::fs::read(&manifest.params_file)
            .with_context(|| format!("reading {:?}", manifest.params_file))?;
        let mut param_bufs = Vec::with_capacity(manifest.params.len());
        let mut param_host = Vec::with_capacity(manifest.params.len());
        for p in &manifest.params {
            let start = p.offset;
            let end = start + p.numel * 4;
            if end > raw.len() {
                bail!("params.bin too small for {}", p.name);
            }
            let floats: Vec<f32> = raw[start..end]
                .chunks_exact(4)
                .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
                .collect();
            let buf = client
                .buffer_from_host_buffer(&floats, &p.shape, None)
                .with_context(|| format!("uploading param {}", p.name))?;
            param_bufs.push(buf);
            param_host.push(floats);
        }
        Ok(Runtime {
            manifest,
            state: Mutex::new(PjrtState {
                client,
                param_bufs,
                exes: HashMap::new(),
            }),
            param_host,
        })
    }

    /// Total model weight bytes (for the memory simulator).
    pub fn weight_bytes(&self) -> usize {
        self.manifest.params.iter().map(|p| p.numel * 4).sum()
    }

    /// Compile (and cache) an executable by manifest name. Also used to
    /// warm programs before serving.
    pub fn executable(&self, name: &str) -> Result<()> {
        let mut st = self.state.lock().unwrap();
        self.compile_locked(&mut st, name)
    }

    fn compile_locked(
        &self,
        st: &mut PjrtState,
        name: &str,
    ) -> Result<()> {
        if st.exes.contains_key(name) {
            return Ok(());
        }
        let spec = self.manifest.exe(name)?.clone();
        let path = self.manifest.dir.join(&spec.file);
        let _t = timer::global().start("runtime.compile");
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| anyhow::anyhow!("parse {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = st
            .client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compile {name}: {e:?}"))?;
        st.exes.insert(name.to_string(), (spec, exe));
        crate::info!("compiled executable '{name}'");
        Ok(())
    }

    /// Execute by name with operands in manifest order.
    pub fn call(&self, name: &str, operands: &[Value]) -> Result<Vec<Value>> {
        let mut st = self.state.lock().unwrap();
        self.compile_locked(&mut st, name)?;
        let st = &*st;
        let (spec, exe) = st.exes.get(name).expect("just compiled");
        if operands.len() != spec.operands.len() {
            bail!(
                "exe {}: expected {} operands, got {}",
                spec.name,
                spec.operands.len(),
                operands.len()
            );
        }
        // validate + upload operands
        let _t_all = timer::global().start("runtime.call");
        let mut inputs: Vec<&xla::PjRtBuffer> =
            st.param_bufs.iter().collect();
        let mut operand_bufs = Vec::with_capacity(operands.len());
        {
            let _t = timer::global().start("runtime.upload");
            for (io, v) in spec.operands.iter().zip(operands) {
                if io.shape != v.shape() {
                    bail!(
                        "exe {} operand '{}': shape {:?} != expected {:?}",
                        spec.name,
                        io.name,
                        v.shape(),
                        io.shape
                    );
                }
                if io.dtype != v.dtype() {
                    bail!(
                        "exe {} operand '{}': dtype mismatch",
                        spec.name,
                        io.name
                    );
                }
                let buf = match v {
                    Value::F32(t) => st.client.buffer_from_host_buffer(
                        &t.data,
                        &t.shape,
                        None,
                    ),
                    Value::I32(t) => st.client.buffer_from_host_buffer(
                        &t.data,
                        &t.shape,
                        None,
                    ),
                }
                .map_err(|e| anyhow::anyhow!("upload operand: {e:?}"))?;
                operand_bufs.push(buf);
            }
        }
        inputs.extend(operand_bufs.iter());

        let out_bufs = {
            let _t = timer::global().start("runtime.execute");
            exe.execute_b(&inputs)
                .map_err(|e| anyhow::anyhow!("execute {}: {e:?}", spec.name))?
        };
        let _t_dl = timer::global().start("runtime.download");
        let tuple = out_bufs[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("fetch result: {e:?}"))?;
        let parts = tuple
            .to_tuple()
            .map_err(|e| anyhow::anyhow!("untuple result: {e:?}"))?;
        if parts.len() != spec.outputs.len() {
            bail!(
                "exe {}: manifest lists {} outputs, program returned {}",
                spec.name,
                spec.outputs.len(),
                parts.len()
            );
        }
        let mut out = Vec::with_capacity(parts.len());
        for (io, lit) in spec.outputs.iter().zip(parts) {
            let v = match io.dtype {
                DType::F32 => {
                    let data = lit
                        .to_vec::<f32>()
                        .map_err(|e| anyhow::anyhow!("to_vec f32: {e:?}"))?;
                    Value::F32(TensorF::new(io.shape.clone(), data)?)
                }
                DType::I32 => {
                    let data = lit
                        .to_vec::<i32>()
                        .map_err(|e| anyhow::anyhow!("to_vec i32: {e:?}"))?;
                    Value::I32(TensorI::new(io.shape.clone(), data)?)
                }
            };
            out.push(v);
        }
        Ok(out)
    }

    /// Load a prior file ([L, m] f32 row-major) from the bundle.
    pub fn load_prior(&self, name: &str) -> Result<Vec<Vec<f32>>> {
        let path = self.manifest.prior_path(name)?;
        let raw = std::fs::read(&path)
            .with_context(|| format!("reading prior {}", path.display()))?;
        let m = self.manifest.model.ffn_m;
        let l = self.manifest.model.n_layers;
        if raw.len() != l * m * 4 {
            bail!(
                "prior {name}: expected {} bytes, found {}",
                l * m * 4,
                raw.len()
            );
        }
        let floats: Vec<f32> = raw
            .chunks_exact(4)
            .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
            .collect();
        Ok(floats.chunks_exact(m).map(|c| c.to_vec()).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn value_accessors() {
        let f = Value::F32(TensorF::zeros(&[2, 2]));
        assert_eq!(f.shape(), &[2, 2]);
        assert_eq!(f.dtype(), DType::F32);
        assert!(f.as_f32().is_ok());
        assert!(f.as_i32().is_err());
        let i = Value::I32(TensorI::zeros(&[3]));
        assert!(i.as_i32().is_ok());
        assert!(i.into_f32().is_err());
    }
}
