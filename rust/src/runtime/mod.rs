//! Runtime: the manifest-driven executable layer behind the engine.
//!
//! # The `ExecBackend` trait
//!
//! Execution is pluggable. Every backend implements the object-safe
//! [`ExecBackend`] trait — `compile` (warm/cache a program by manifest
//! name), `call` (execute with operands already validated against the
//! manifest), `capabilities` (what the engine may rely on), and an
//! optional `prior` hook for backends that synthesize their global
//! priors instead of reading bundle files. [`Runtime`] owns one
//! `Box<dyn ExecBackend>` selected **by name** at load time
//! ([`Runtime::load_with_backend`], [`BACKEND_NAMES`]); everything
//! above the runtime — engine, GLASS mask plumbing, server, benches —
//! talks to the trait and probes [`Capabilities`], never a concrete
//! backend type.
//!
//! # Capability matrix
//!
//! | backend  | native_masked_ffn | chunked_prefill | needs_warmup | deterministic |
//! |----------|-------------------|-----------------|--------------|---------------|
//! | `sim`    | no                | yes             | no           | yes           |
//! | `cpu-q8` | **yes**           | yes             | no           | yes           |
//! | `pjrt`   | no                | yes             | yes          | no            |
//!
//! * **`sim`** ([`sim`]): deterministic pure-Rust toy model; the
//!   offline default and the semantic oracle for the test corpus.
//! * **`cpu-q8`** ([`cpu_q8`]): int8 weight-quantized CPU kernels
//!   ([`quant`]) that consume the GLASS mask as a kept-row list and
//!   never load masked-out FFN rows — density 0.3 is ~0.3× real FFN
//!   memory traffic.
//! * **`pjrt`** ([`pjrt`], `--features pjrt`): AOT-compiled HLO through
//!   the `xla` crate's PJRT CPU client; weights upload once, per-call
//!   traffic is operands only. Needs explicit warm-up (`compile`) and
//!   is not bitwise-reproducible across program boundaries.
//!
//! `capabilities().deterministic` is the replacement for the old
//! `Runtime::is_simulated()` special-casing: tests gate bitwise
//! assertions on it, and the engine uses `native_masked_ffn` /
//! `needs_warmup` instead of asking *which* backend it has.
//!
//! # Adding a backend
//!
//! 1. Create `runtime/<name>.rs` with a struct implementing
//!    [`ExecBackend`] over the manifest's executable contract
//!    (`{prefill,prefill_chunk,decode,decode_topk,score,generate}_b{b}`
//!    — operands arrive pre-validated in manifest order).
//! 2. Report honest [`Capabilities`]; claim `deterministic` only if
//!    repeated calls and fused/step paths agree **bitwise**.
//! 3. Register the name in [`BACKEND_NAMES`] and construct it in
//!    `make_backend`; config/CLI validation picks the name up from the
//!    registry automatically.
//! 4. Run the tier-1 suite with `GLASS_TEST_BACKEND=<name>` — the
//!    integration corpus is backend-parameterized and is the contract.
//!
//! Operand count/shape/dtype validation against the manifest happens
//! in [`Runtime::call`], identically for every backend.

pub mod cpu_q8;
pub mod manifest;
pub mod quant;
pub mod sim;

#[cfg(feature = "pjrt")]
mod pjrt;

pub use manifest::{DType, ExeSpec, IoSpec, Manifest, ModelSpec, ParamSpec};

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::tensor::{TensorF, TensorI};
use crate::util::timer;

/// A host-side value crossing the runtime boundary.
#[derive(Debug, Clone)]
pub enum Value {
    F32(TensorF),
    I32(TensorI),
}

impl Value {
    pub fn shape(&self) -> &[usize] {
        match self {
            Value::F32(t) => &t.shape,
            Value::I32(t) => &t.shape,
        }
    }

    pub fn dtype(&self) -> DType {
        match self {
            Value::F32(_) => DType::F32,
            Value::I32(_) => DType::I32,
        }
    }

    pub fn as_f32(&self) -> Result<&TensorF> {
        match self {
            Value::F32(t) => Ok(t),
            Value::I32(_) => bail!("expected f32 value"),
        }
    }

    pub fn as_i32(&self) -> Result<&TensorI> {
        match self {
            Value::I32(t) => Ok(t),
            Value::F32(_) => bail!("expected i32 value"),
        }
    }

    pub fn into_f32(self) -> Result<TensorF> {
        match self {
            Value::F32(t) => Ok(t),
            Value::I32(_) => bail!("expected f32 value"),
        }
    }

    pub fn into_i32(self) -> Result<TensorI> {
        match self {
            Value::I32(t) => Ok(t),
            Value::F32(_) => bail!("expected i32 value"),
        }
    }
}

/// What the engine/server may rely on from a backend. Probed through
/// [`Runtime::capabilities`]; this is the public replacement for
/// `is_sim()`-style downcasts.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Capabilities {
    /// The backend consumes the GLASS mask inside its own kernels and
    /// skips masked-out FFN rows entirely (density ⇒ real FLOP/traffic
    /// savings). When false, masking only shapes statistics/quality.
    pub native_masked_ffn: bool,
    /// `prefill_chunk_b*` executables are implemented (chunked prefill
    /// and prefix-cache resume are available).
    pub chunked_prefill: bool,
    /// Programs must be compiled/warmed before serving traffic
    /// (first-call latency would otherwise hit a request).
    pub needs_warmup: bool,
    /// Repeated calls, fused vs. step paths, and chunk partitions agree
    /// **bitwise**. Tests gate exact-equality assertions on this.
    pub deterministic: bool,
}

/// An execution backend behind [`Runtime`]. Object-safe; implementors
/// must be shareable across shard threads (`Send + Sync`).
pub trait ExecBackend: Send + Sync {
    /// Stable registry name (`"sim"`, `"cpu-q8"`, `"pjrt"`).
    fn name(&self) -> &'static str;

    /// What the layers above may rely on. Must be constant for the
    /// lifetime of the backend.
    fn capabilities(&self) -> Capabilities;

    /// Compile (or otherwise warm) an executable by manifest name; a
    /// validating no-op for backends with nothing to compile.
    fn compile(&self, manifest: &Manifest, name: &str) -> Result<()>;

    /// Execute. Operands are already validated against the `ExeSpec`
    /// (count, shape, dtype) in manifest order.
    fn call(
        &self,
        manifest: &Manifest,
        spec: &ExeSpec,
        operands: &[Value],
    ) -> Result<Vec<Value>>;

    /// Backend-synthesized global prior, or `None` to read the prior
    /// from the artifact bundle ([L, m] f32 row-major file).
    fn prior(&self, _name: &str) -> Option<Result<Vec<Vec<f32>>>> {
        None
    }
}

/// Every selectable backend name. `"auto"` resolves to `pjrt` when the
/// feature is compiled in and an artifact bundle is loaded, else `sim`.
pub const BACKEND_NAMES: [&str; 4] = ["auto", "sim", "cpu-q8", "pjrt"];

/// Reject unknown backend names with the full registry in the error —
/// used by config/CLI parsing so typos fail at parse time, not at
/// first request.
pub fn validate_backend_name(name: &str) -> Result<()> {
    if BACKEND_NAMES.contains(&name) {
        Ok(())
    } else {
        bail!(
            "unknown backend '{name}' (expected one of: {})",
            BACKEND_NAMES.join(", ")
        )
    }
}

/// Resolve `"auto"` to the concrete default for this build.
fn resolve_backend_name(name: &str) -> Result<&'static str> {
    validate_backend_name(name)?;
    Ok(match name {
        "auto" => {
            if cfg!(feature = "pjrt") {
                "pjrt"
            } else {
                "sim"
            }
        }
        "sim" => "sim",
        "cpu-q8" => "cpu-q8",
        "pjrt" => "pjrt",
        _ => unreachable!("validated above"),
    })
}

/// The runtime: the manifest, the selected backend, and host copies of
/// the weights (for the memory simulator and diagnostics).
pub struct Runtime {
    pub manifest: Manifest,
    backend: Box<dyn ExecBackend>,
    /// Raw host copy of the weights (memsim + weight inspection need it).
    pub param_host: Vec<Vec<f32>>,
}

impl Runtime {
    /// Load the artifact bundle at `dir` on the default (`"auto"`)
    /// backend: PJRT when compiled in, else the simulator.
    pub fn load(dir: &Path) -> Result<Runtime> {
        Runtime::load_with_backend(dir, "auto")
    }

    /// Load the artifact bundle at `dir` on the backend selected by
    /// registry name (see [`BACKEND_NAMES`]).
    pub fn load_with_backend(dir: &Path, backend: &str) -> Result<Runtime> {
        let resolved = resolve_backend_name(backend)?;
        let manifest = Manifest::load(dir)?;
        // only PJRT uploads weights to a device; the other backends can
        // fall back to deterministic synthetic weights when params.bin
        // is absent (weight-dependent tooling keeps working)
        let param_host = load_params(&manifest, resolved == "pjrt")?;
        if backend == "auto" && resolved == "sim" {
            crate::info!(
                "pjrt feature disabled — executing '{}' on the simulator \
                 backend",
                dir.display()
            );
        }
        let backend = make_backend(resolved, &manifest, &param_host)?;
        Ok(Runtime {
            manifest,
            backend,
            param_host,
        })
    }

    /// Build a fully in-memory runtime on the simulator backend: a
    /// synthetic manifest, deterministic weights, and hash-derived
    /// priors. Works with zero files on disk.
    pub fn synthetic() -> Runtime {
        Runtime::synthetic_with_backend("sim")
            .expect("sim backend construction is infallible")
    }

    /// In-memory synthetic runtime on a named backend (`"sim"` or
    /// `"cpu-q8"`; `"auto"` resolves to `"sim"` — there is no artifact
    /// bundle for PJRT to load).
    pub fn synthetic_with_backend(backend: &str) -> Result<Runtime> {
        validate_backend_name(backend)?;
        let name = if backend == "auto" { "sim" } else { backend };
        if name == "pjrt" {
            bail!("backend 'pjrt' needs an artifact bundle (use `load`)");
        }
        let manifest = sim::synthetic_manifest();
        let param_host: Vec<Vec<f32>> = manifest
            .params
            .iter()
            .map(|p| sim::SimBackend::param_values(&p.name, p.numel))
            .collect();
        let backend = make_backend(name, &manifest, &param_host)?;
        Ok(Runtime {
            manifest,
            backend,
            param_host,
        })
    }

    /// The resolved registry name of the executing backend.
    pub fn backend_name(&self) -> &'static str {
        self.backend.name()
    }

    /// What the executing backend guarantees (see [`Capabilities`]).
    pub fn capabilities(&self) -> Capabilities {
        self.backend.capabilities()
    }

    /// Total model weight bytes (for the memory simulator).
    pub fn weight_bytes(&self) -> usize {
        self.manifest.params.iter().map(|p| p.numel * 4).sum()
    }

    /// Compile (and cache) an executable by manifest name. Also used to
    /// warm programs before serving; backends without a compile step
    /// just validate the name.
    pub fn executable(&self, name: &str) -> Result<()> {
        self.backend.compile(&self.manifest, name)
    }

    /// Execute by name with operands in manifest order.
    pub fn call(&self, name: &str, operands: &[Value]) -> Result<Vec<Value>> {
        let spec = self.manifest.exe(name)?;
        if operands.len() != spec.operands.len() {
            bail!(
                "exe {}: expected {} operands, got {}",
                spec.name,
                spec.operands.len(),
                operands.len()
            );
        }
        let _t_all = timer::global().start("runtime.call");
        for (io, v) in spec.operands.iter().zip(operands) {
            if io.shape != v.shape() {
                bail!(
                    "exe {} operand '{}': shape {:?} != expected {:?}",
                    spec.name,
                    io.name,
                    v.shape(),
                    io.shape
                );
            }
            if io.dtype != v.dtype() {
                bail!(
                    "exe {} operand '{}': dtype mismatch",
                    spec.name,
                    io.name
                );
            }
        }
        self.backend.call(&self.manifest, spec, operands)
    }

    /// Load a prior by name: from the backend when it synthesizes its
    /// own, else from the bundle ([L, m] f32 row-major file).
    pub fn load_prior(&self, name: &str) -> Result<Vec<Vec<f32>>> {
        if let Some(r) = self.backend.prior(name) {
            return r;
        }
        let path = self.manifest.prior_path(name)?;
        let raw = std::fs::read(&path)
            .with_context(|| format!("reading prior {}", path.display()))?;
        let m = self.manifest.model.ffn_m;
        let l = self.manifest.model.n_layers;
        if raw.len() != l * m * 4 {
            bail!(
                "prior {name}: expected {} bytes, found {}",
                l * m * 4,
                raw.len()
            );
        }
        let floats: Vec<f32> = raw
            .chunks_exact(4)
            .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
            .collect();
        Ok(floats.chunks_exact(m).map(|c| c.to_vec()).collect())
    }
}

/// Construct a backend by resolved registry name.
fn make_backend(
    name: &str,
    manifest: &Manifest,
    param_host: &[Vec<f32>],
) -> Result<Box<dyn ExecBackend>> {
    match name {
        "sim" => Ok(Box::new(sim::SimBackend::new(manifest.model.clone()))),
        "cpu-q8" => Ok(Box::new(cpu_q8::CpuQ8Backend::new(
            manifest, param_host,
        )?)),
        #[cfg(feature = "pjrt")]
        "pjrt" => Ok(Box::new(pjrt::PjrtBackend::load(
            &manifest.params,
            param_host,
        )?)),
        #[cfg(not(feature = "pjrt"))]
        "pjrt" => bail!(
            "backend 'pjrt' is not compiled into this binary \
             (rebuild with --features pjrt)"
        ),
        other => bail!("unknown backend '{other}'"),
    }
}

/// Read params.bin per the manifest inventory. When the file is absent
/// and the backend does not strictly need real weights (`require_file`
/// is false), fall back to deterministic synthetic weights so
/// weight-dependent tooling (memsim, `glass info`, cpu-q8 quantization)
/// still works.
fn load_params(manifest: &Manifest, require_file: bool) -> Result<Vec<Vec<f32>>> {
    match std::fs::read(&manifest.params_file) {
        Ok(raw) => {
            let mut param_host = Vec::with_capacity(manifest.params.len());
            for p in &manifest.params {
                let start = p.offset;
                let end = start + p.numel * 4;
                if end > raw.len() {
                    bail!("params.bin too small for {}", p.name);
                }
                let floats: Vec<f32> = raw[start..end]
                    .chunks_exact(4)
                    .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
                    .collect();
                param_host.push(floats);
            }
            Ok(param_host)
        }
        Err(e) => {
            if require_file {
                Err(e).with_context(|| {
                    format!("reading {:?}", manifest.params_file)
                })
            } else {
                Ok(manifest
                    .params
                    .iter()
                    .map(|p| sim::SimBackend::param_values(&p.name, p.numel))
                    .collect())
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn value_accessors() {
        let f = Value::F32(TensorF::zeros(&[2, 2]));
        assert_eq!(f.shape(), &[2, 2]);
        assert_eq!(f.dtype(), DType::F32);
        assert!(f.as_f32().is_ok());
        assert!(f.as_i32().is_err());
        let i = Value::I32(TensorI::zeros(&[3]));
        assert!(i.as_i32().is_ok());
        assert!(i.into_f32().is_err());
    }

    #[test]
    fn synthetic_runtime_round_trips() {
        let rt = Runtime::synthetic();
        assert_eq!(rt.backend_name(), "sim");
        assert!(rt.capabilities().deterministic);
        assert!(!rt.capabilities().native_masked_ffn);
        assert!(rt.weight_bytes() > 0);
        assert_eq!(rt.param_host.len(), rt.manifest.params.len());
        // operand validation is backend-independent
        assert!(rt.call("decode_b1", &[]).is_err());
        assert!(rt.executable("prefill_b4").is_ok());
        assert!(rt.executable("nope_b4").is_err());
        // priors resolve through the backend hook
        let p = rt.load_prior("a_nps").unwrap();
        assert_eq!(p.len(), rt.manifest.model.n_layers);
    }

    #[test]
    fn cpu_q8_synthetic_runtime_round_trips() {
        let rt = Runtime::synthetic_with_backend("cpu-q8").unwrap();
        assert_eq!(rt.backend_name(), "cpu-q8");
        let caps = rt.capabilities();
        assert!(caps.native_masked_ffn);
        assert!(caps.chunked_prefill);
        assert!(caps.deterministic);
        assert!(!caps.needs_warmup);
        assert!(rt.executable("prefill_b4").is_ok());
        // priors are shared with the sim oracle, so λ fusion and the
        // GLASS boundary see identical inputs on both backends
        let sim_rt = Runtime::synthetic();
        assert_eq!(
            rt.load_prior("a_nps").unwrap(),
            sim_rt.load_prior("a_nps").unwrap()
        );
    }

    #[test]
    fn unknown_backend_names_are_rejected() {
        assert!(validate_backend_name("sim").is_ok());
        assert!(validate_backend_name("cpu-q8").is_ok());
        assert!(validate_backend_name("auto").is_ok());
        let err = validate_backend_name("cuda")
            .unwrap_err()
            .to_string();
        assert!(err.contains("cuda") && err.contains("cpu-q8"), "{err}");
        assert!(Runtime::synthetic_with_backend("cuda").is_err());
        assert!(Runtime::synthetic_with_backend("pjrt").is_err());
    }

    #[test]
    fn auto_resolves_to_sim_for_synthetic() {
        let rt = Runtime::synthetic_with_backend("auto").unwrap();
        assert_eq!(rt.backend_name(), "sim");
    }
}
