//! Runtime: the manifest-driven executable layer behind the engine.
//!
//! Two interchangeable backends sit behind [`Runtime::call`]:
//!
//! * **PJRT** (`--features pjrt`): loads AOT-compiled HLO text through
//!   the `xla` crate's PJRT CPU client — see [`pjrt`]. Model parameters
//!   are uploaded once; per-call traffic is operands only.
//! * **Simulator** (default): a deterministic pure-Rust model with the
//!   same executable contract — see [`sim`]. Used whenever the real
//!   XLA toolchain or the artifact bundle is unavailable (offline CI,
//!   tests, benches), via [`Runtime::synthetic`] or as the execution
//!   backend for an on-disk manifest.
//!
//! Operand count/shape/dtype validation against the manifest happens
//! here, identically for both backends.

pub mod manifest;
pub mod sim;

#[cfg(feature = "pjrt")]
mod pjrt;

pub use manifest::{DType, ExeSpec, IoSpec, Manifest, ModelSpec, ParamSpec};

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::tensor::{TensorF, TensorI};
use crate::util::timer;

/// A host-side value crossing the runtime boundary.
#[derive(Debug, Clone)]
pub enum Value {
    F32(TensorF),
    I32(TensorI),
}

impl Value {
    pub fn shape(&self) -> &[usize] {
        match self {
            Value::F32(t) => &t.shape,
            Value::I32(t) => &t.shape,
        }
    }

    pub fn dtype(&self) -> DType {
        match self {
            Value::F32(_) => DType::F32,
            Value::I32(_) => DType::I32,
        }
    }

    pub fn as_f32(&self) -> Result<&TensorF> {
        match self {
            Value::F32(t) => Ok(t),
            Value::I32(_) => bail!("expected f32 value"),
        }
    }

    pub fn as_i32(&self) -> Result<&TensorI> {
        match self {
            Value::I32(t) => Ok(t),
            Value::F32(_) => bail!("expected i32 value"),
        }
    }

    pub fn into_f32(self) -> Result<TensorF> {
        match self {
            Value::F32(t) => Ok(t),
            Value::I32(_) => bail!("expected f32 value"),
        }
    }

    pub fn into_i32(self) -> Result<TensorI> {
        match self {
            Value::I32(t) => Ok(t),
            Value::F32(_) => bail!("expected i32 value"),
        }
    }
}

enum Backend {
    Sim(sim::SimBackend),
    #[cfg(feature = "pjrt")]
    Pjrt(pjrt::PjrtBackend),
}

/// The runtime: the manifest, the selected backend, and host copies of
/// the weights (for the memory simulator and diagnostics).
pub struct Runtime {
    pub manifest: Manifest,
    backend: Backend,
    /// Raw host copy of the weights (memsim + weight inspection need it).
    pub param_host: Vec<Vec<f32>>,
}

impl Runtime {
    /// Load the artifact bundle at `dir`. With the `pjrt` feature the
    /// HLO programs are compiled and executed through PJRT; without it,
    /// the manifest drives the simulator backend.
    pub fn load(dir: &Path) -> Result<Runtime> {
        let manifest = Manifest::load(dir)?;
        let param_host = load_params(&manifest)?;

        #[cfg(feature = "pjrt")]
        let backend = Backend::Pjrt(pjrt::PjrtBackend::load(
            &manifest.params,
            &param_host,
        )?);
        #[cfg(not(feature = "pjrt"))]
        let backend = {
            crate::info!(
                "pjrt feature disabled — executing '{}' on the simulator \
                 backend",
                dir.display()
            );
            Backend::Sim(sim::SimBackend::new(manifest.model.clone()))
        };

        Ok(Runtime {
            manifest,
            backend,
            param_host,
        })
    }

    /// Build a fully in-memory runtime on the simulator backend: a
    /// synthetic manifest, deterministic weights, and hash-derived
    /// priors. Works with zero files on disk.
    pub fn synthetic() -> Runtime {
        let manifest = sim::synthetic_manifest();
        let param_host = manifest
            .params
            .iter()
            .map(|p| sim::SimBackend::param_values(&p.name, p.numel))
            .collect();
        let backend = Backend::Sim(sim::SimBackend::new(manifest.model.clone()));
        Runtime {
            manifest,
            backend,
            param_host,
        }
    }

    /// True when calls execute on the simulator backend.
    pub fn is_simulated(&self) -> bool {
        matches!(self.backend, Backend::Sim(_))
    }

    /// Total model weight bytes (for the memory simulator).
    pub fn weight_bytes(&self) -> usize {
        self.manifest.params.iter().map(|p| p.numel * 4).sum()
    }

    /// Compile (and cache) an executable by manifest name. Also used to
    /// warm programs before serving; a no-op on the simulator beyond
    /// validating the name.
    pub fn executable(&self, name: &str) -> Result<()> {
        self.manifest.exe(name)?;
        match &self.backend {
            Backend::Sim(_) => Ok(()),
            #[cfg(feature = "pjrt")]
            Backend::Pjrt(p) => p.compile(&self.manifest, name),
        }
    }

    /// Execute by name with operands in manifest order.
    pub fn call(&self, name: &str, operands: &[Value]) -> Result<Vec<Value>> {
        let spec = self.manifest.exe(name)?;
        if operands.len() != spec.operands.len() {
            bail!(
                "exe {}: expected {} operands, got {}",
                spec.name,
                spec.operands.len(),
                operands.len()
            );
        }
        let _t_all = timer::global().start("runtime.call");
        for (io, v) in spec.operands.iter().zip(operands) {
            if io.shape != v.shape() {
                bail!(
                    "exe {} operand '{}': shape {:?} != expected {:?}",
                    spec.name,
                    io.name,
                    v.shape(),
                    io.shape
                );
            }
            if io.dtype != v.dtype() {
                bail!(
                    "exe {} operand '{}': dtype mismatch",
                    spec.name,
                    io.name
                );
            }
        }
        match &self.backend {
            Backend::Sim(s) => {
                let _t = timer::global().start("runtime.execute");
                s.call(name, operands)
            }
            #[cfg(feature = "pjrt")]
            Backend::Pjrt(p) => p.call(&self.manifest, spec, operands),
        }
    }

    /// Load a prior by name: from the simulator when simulated, else
    /// from the bundle ([L, m] f32 row-major file).
    pub fn load_prior(&self, name: &str) -> Result<Vec<Vec<f32>>> {
        if let Backend::Sim(s) = &self.backend {
            return s.prior(name);
        }
        let path = self.manifest.prior_path(name)?;
        let raw = std::fs::read(&path)
            .with_context(|| format!("reading prior {}", path.display()))?;
        let m = self.manifest.model.ffn_m;
        let l = self.manifest.model.n_layers;
        if raw.len() != l * m * 4 {
            bail!(
                "prior {name}: expected {} bytes, found {}",
                l * m * 4,
                raw.len()
            );
        }
        let floats: Vec<f32> = raw
            .chunks_exact(4)
            .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
            .collect();
        Ok(floats.chunks_exact(m).map(|c| c.to_vec()).collect())
    }
}

/// Read params.bin per the manifest inventory. When the file is absent
/// and we are not going to upload to PJRT (simulator execution), fall
/// back to deterministic synthetic weights so weight-dependent tooling
/// (memsim, `glass info`) still works.
fn load_params(manifest: &Manifest) -> Result<Vec<Vec<f32>>> {
    match std::fs::read(&manifest.params_file) {
        Ok(raw) => {
            let mut param_host = Vec::with_capacity(manifest.params.len());
            for p in &manifest.params {
                let start = p.offset;
                let end = start + p.numel * 4;
                if end > raw.len() {
                    bail!("params.bin too small for {}", p.name);
                }
                let floats: Vec<f32> = raw[start..end]
                    .chunks_exact(4)
                    .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
                    .collect();
                param_host.push(floats);
            }
            Ok(param_host)
        }
        Err(e) => {
            if cfg!(feature = "pjrt") {
                Err(e).with_context(|| {
                    format!("reading {:?}", manifest.params_file)
                })
            } else {
                Ok(manifest
                    .params
                    .iter()
                    .map(|p| sim::SimBackend::param_values(&p.name, p.numel))
                    .collect())
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn value_accessors() {
        let f = Value::F32(TensorF::zeros(&[2, 2]));
        assert_eq!(f.shape(), &[2, 2]);
        assert_eq!(f.dtype(), DType::F32);
        assert!(f.as_f32().is_ok());
        assert!(f.as_i32().is_err());
        let i = Value::I32(TensorI::zeros(&[3]));
        assert!(i.as_i32().is_ok());
        assert!(i.into_f32().is_err());
    }

    #[test]
    fn synthetic_runtime_round_trips() {
        let rt = Runtime::synthetic();
        assert!(rt.is_simulated());
        assert!(rt.weight_bytes() > 0);
        assert_eq!(rt.param_host.len(), rt.manifest.params.len());
        // operand validation is backend-independent
        assert!(rt.call("decode_b1", &[]).is_err());
        assert!(rt.executable("prefill_b4").is_ok());
        assert!(rt.executable("nope_b4").is_err());
        // priors resolve through the simulator
        let p = rt.load_prior("a_nps").unwrap();
        assert_eq!(p.len(), rt.manifest.model.n_layers);
    }
}
