//! Per-row symmetric int8 quantization and blocked GEMV kernels for the
//! [`super::cpu_q8`] backend.
//!
//! Design constraints (all load-bearing for the test suite):
//!
//! * **Integer accumulation.** Weights and activations are quantized to
//!   int8 and dot products accumulate in i32. Integer addition is
//!   associative, so the scalar, AVX2, and NEON paths produce the SAME
//!   i32 no matter how lanes are grouped — the float result
//!   (`i32 as f32 * w_scale * x_scale`) is therefore bit-identical
//!   across every SIMD path by construction, not by tolerance.
//! * **Masked row skipping.** [`masked_gemv`] takes the GLASS kept-row
//!   list and touches ONLY those rows: a masked-out row's int8 data and
//!   scale are never loaded, so density d means ~d× the FFN memory
//!   traffic (measured by `bench_decode`'s `cpu-q8 GEMV` rows and
//!   proven by the poisoned-row canary in `cpu_q8`).
//! * **Blocked inner loops.** The scalar path accumulates into a fixed
//!   8-lane block so LLVM can autovectorize it even without the
//!   `std::arch` fast paths; the AVX2/NEON paths are selected at
//!   runtime ([`detect`]) with the scalar loop as universal fallback.
//!
//! Overflow bound: each i8×i8 product is ≤ 127·127 = 16129, so an i32
//! accumulator is safe for any row length below ~2^17 elements — far
//! above any model dimension this crate handles (asserted in
//! [`QuantMatrix::from_rows`]).

use std::sync::mpsc;

use anyhow::{bail, Result};

use crate::util::threadpool::ThreadPool;

/// Symmetric quantization range: [-127, 127] (−128 unused so the
/// representable grid is symmetric around zero).
pub const Q_MAX: f32 = 127.0;

/// Row lengths are capped so i32 GEMV accumulators cannot overflow.
pub const MAX_COLS: usize = 1 << 17;

// ------------------------------------------------------ SIMD dispatch

/// Which inner-loop implementation a GEMV call uses. All variants
/// return bit-identical results (integer accumulation, see module docs).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Simd {
    /// Blocked scalar loop (autovectorizable; the universal fallback).
    Scalar,
    /// `std::arch::x86_64` AVX2 path (`_mm256_madd_epi16`).
    Avx2,
    /// `std::arch::aarch64` NEON path (`vmull_s8` + pairwise widen).
    Neon,
}

impl Simd {
    /// Short label for telemetry and bench rows.
    pub fn label(self) -> &'static str {
        match self {
            Simd::Scalar => "scalar",
            Simd::Avx2 => "avx2",
            Simd::Neon => "neon",
        }
    }
}

/// Runtime feature detection: the best kernel available on this host.
pub fn detect() -> Simd {
    #[cfg(target_arch = "x86_64")]
    {
        if std::arch::is_x86_feature_detected!("avx2") {
            return Simd::Avx2;
        }
    }
    #[cfg(target_arch = "aarch64")]
    {
        // NEON (asimd) is a baseline aarch64 feature.
        return Simd::Neon;
    }
    #[allow(unreachable_code)]
    Simd::Scalar
}

/// Every kernel runnable on this host (scalar always; used by the
/// bit-for-bit agreement tests).
pub fn available() -> Vec<Simd> {
    let mut v = vec![Simd::Scalar];
    let best = detect();
    if best != Simd::Scalar {
        v.push(best);
    }
    v
}

// ------------------------------------------------------- quantization

/// Per-row symmetric int8 quantization: `scale = max|x| / 127`,
/// `q = round(x / scale)` clamped to [-127, 127]. An all-zero row gets
/// scale 1.0 (and all-zero codes) so dequantization never divides by 0.
pub fn quantize_row(src: &[f32]) -> (Vec<i8>, f32) {
    let mut q = Vec::with_capacity(src.len());
    let scale = quantize_into(src, &mut q);
    (q, scale)
}

/// In-place variant of [`quantize_row`] reusing the output buffer (the
/// per-token activation path); returns the scale.
pub fn quantize_into(src: &[f32], out: &mut Vec<i8>) -> f32 {
    out.clear();
    let maxabs = src.iter().fold(0.0f32, |m, &x| m.max(x.abs()));
    let scale = if maxabs > 0.0 { maxabs / Q_MAX } else { 1.0 };
    let inv = 1.0 / scale;
    out.extend(src.iter().map(|&x| {
        (x * inv).round().clamp(-Q_MAX, Q_MAX) as i8
    }));
    scale
}

/// A row-major int8 matrix with one symmetric scale per row. Rows are
/// the GEMV output units, so the GLASS mask maps 1:1 onto row skips.
#[derive(Debug, Clone)]
pub struct QuantMatrix {
    rows: usize,
    cols: usize,
    data: Vec<i8>,
    scales: Vec<f32>,
}

impl QuantMatrix {
    /// Quantize a row-major f32 matrix (`src.len() == rows*cols`).
    pub fn from_rows(rows: usize, cols: usize, src: &[f32]) -> Result<QuantMatrix> {
        if src.len() != rows * cols {
            bail!(
                "QuantMatrix::from_rows: {} values for {rows}x{cols}",
                src.len()
            );
        }
        if cols > MAX_COLS {
            bail!("QuantMatrix: {cols} cols exceeds i32-safe bound {MAX_COLS}");
        }
        let mut data = Vec::with_capacity(rows * cols);
        let mut scales = Vec::with_capacity(rows);
        for r in 0..rows {
            let (q, s) = quantize_row(&src[r * cols..(r + 1) * cols]);
            data.extend_from_slice(&q);
            scales.push(s);
        }
        Ok(QuantMatrix {
            rows,
            cols,
            data,
            scales,
        })
    }

    /// Quantize the TRANSPOSE of a row-major `src_rows x src_cols` f32
    /// matrix: output row `j` is `src[:, j]`. Used to store the
    /// manifest's `[d, m]` up/gate projections as `[m, d]` so each FFN
    /// unit is one contiguous, individually skippable row.
    pub fn from_columns(
        src_rows: usize,
        src_cols: usize,
        src: &[f32],
    ) -> Result<QuantMatrix> {
        if src.len() != src_rows * src_cols {
            bail!(
                "QuantMatrix::from_columns: {} values for {src_rows}x{src_cols}",
                src.len()
            );
        }
        let mut t = vec![0.0f32; src.len()];
        for r in 0..src_rows {
            for c in 0..src_cols {
                t[c * src_rows + r] = src[r * src_cols + c];
            }
        }
        QuantMatrix::from_rows(src_cols, src_rows, &t)
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    /// The int8 codes of row `r`.
    pub fn row(&self, r: usize) -> &[i8] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// The symmetric scale of row `r`.
    pub fn scale(&self, r: usize) -> f32 {
        self.scales[r]
    }

    /// Dequantize row `r` to f32 (`q * scale`).
    pub fn dequantize_row(&self, r: usize) -> Vec<f32> {
        let s = self.scales[r];
        self.row(r).iter().map(|&q| q as f32 * s).collect()
    }

    /// Quantized storage footprint in bytes (codes + scales).
    pub fn weight_bytes(&self) -> usize {
        self.data.len() + self.scales.len() * 4
    }

    /// Canary helper: poison row `r` so ANY read of it propagates NaN
    /// into downstream float math. Proves masked-out rows are never
    /// loaded (see the `cpu_q8` poisoned-weight canary test).
    pub fn poison_row(&mut self, r: usize) {
        self.scales[r] = f32::NAN;
        for q in &mut self.data[r * self.cols..(r + 1) * self.cols] {
            *q = i8::MAX;
        }
    }
}

// ------------------------------------------------------- dot kernels

/// Integer dot product of two int8 slices via the selected kernel.
/// Slices longer than the shorter operand are truncated to match.
pub fn dot_q8(simd: Simd, a: &[i8], b: &[i8]) -> i32 {
    let n = a.len().min(b.len());
    let (a, b) = (&a[..n], &b[..n]);
    match simd {
        Simd::Scalar => dot_scalar(a, b),
        #[cfg(target_arch = "x86_64")]
        // SAFETY: Simd::Avx2 is only ever produced by `detect()` after
        // `is_x86_feature_detected!("avx2")` returned true on this host.
        Simd::Avx2 => unsafe { dot_avx2(a, b) },
        #[cfg(target_arch = "aarch64")]
        Simd::Neon => dot_neon(a, b),
        #[allow(unreachable_patterns)]
        _ => dot_scalar(a, b),
    }
}

/// Blocked scalar kernel: a fixed 8-lane accumulator block mirrors the
/// SIMD lane structure and lets LLVM autovectorize the inner loop.
fn dot_scalar(a: &[i8], b: &[i8]) -> i32 {
    let n = a.len();
    let chunks = n / 8;
    let mut acc = [0i32; 8];
    for c in 0..chunks {
        let o = c * 8;
        for lane in 0..8 {
            acc[lane] += a[o + lane] as i32 * b[o + lane] as i32;
        }
    }
    let mut s: i32 = acc.iter().sum();
    for i in chunks * 8..n {
        s += a[i] as i32 * b[i] as i32;
    }
    s
}

/// AVX2 kernel: 16 int8 lanes per step, widened to i16 and pair-summed
/// into 8 i32 lanes by `_mm256_madd_epi16`. No float math → the result
/// equals the scalar kernel's bit for bit.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
// SAFETY: `unsafe fn` only because of `#[target_feature]` — the sole
// caller is `dot_q8`, which dispatches here after runtime detection.
unsafe fn dot_avx2(a: &[i8], b: &[i8]) -> i32 {
    use std::arch::x86_64::*;
    let n = a.len();
    let mut i = 0usize;
    // SAFETY: all loads below read 16 bytes at `ptr + i` with
    // `i + 16 <= n`, inside the slice bounds; alignment is not required
    // by the unaligned load intrinsics.
    let mut acc = unsafe { _mm256_setzero_si256() };
    while i + 16 <= n {
        // SAFETY: bounds checked by the loop condition (see above).
        unsafe {
            let va = _mm_loadu_si128(a.as_ptr().add(i) as *const __m128i);
            let vb = _mm_loadu_si128(b.as_ptr().add(i) as *const __m128i);
            let wa = _mm256_cvtepi8_epi16(va);
            let wb = _mm256_cvtepi8_epi16(vb);
            acc = _mm256_add_epi32(acc, _mm256_madd_epi16(wa, wb));
        }
        i += 16;
    }
    let mut lanes = [0i32; 8];
    // SAFETY: `lanes` is exactly 32 bytes, the store width.
    unsafe {
        _mm256_storeu_si256(lanes.as_mut_ptr() as *mut __m256i, acc);
    }
    let mut s: i32 = lanes.iter().sum();
    while i < n {
        s += a[i] as i32 * b[i] as i32;
        i += 1;
    }
    s
}

/// NEON kernel: 16 int8 lanes per step via `vmull_s8` (i8×i8→i16) and
/// `vpadalq_s16` (pairwise widen-accumulate into i32). Integer-only,
/// so bit-identical to the scalar kernel.
#[cfg(target_arch = "aarch64")]
fn dot_neon(a: &[i8], b: &[i8]) -> i32 {
    use std::arch::aarch64::*;
    let n = a.len();
    let mut i = 0usize;
    // SAFETY: NEON (asimd) is a baseline aarch64 target feature, and
    // every load reads 16 bytes at `ptr + i` with `i + 16 <= n`.
    let mut s = unsafe {
        let mut acc = vdupq_n_s32(0);
        while i + 16 <= n {
            let va = vld1q_s8(a.as_ptr().add(i));
            let vb = vld1q_s8(b.as_ptr().add(i));
            let lo = vmull_s8(vget_low_s8(va), vget_low_s8(vb));
            let hi = vmull_s8(vget_high_s8(va), vget_high_s8(vb));
            acc = vpadalq_s16(acc, lo);
            acc = vpadalq_s16(acc, hi);
            i += 16;
        }
        vaddvq_s32(acc)
    };
    while i < n {
        s += a[i] as i32 * b[i] as i32;
        i += 1;
    }
    s
}

// ------------------------------------------------------------- GEMV

/// Masked GEMV: for each `j` in `rows`,
/// `out[j] = dot(w.row(j), x_q) * w.scale(j) * x_scale`.
/// Rows NOT listed are never loaded and their `out` slots are left
/// untouched (the caller pre-fills them — typically with zeros).
pub fn masked_gemv(
    simd: Simd,
    w: &QuantMatrix,
    x_q: &[i8],
    x_scale: f32,
    rows: &[usize],
    out: &mut [f32],
) {
    for &j in rows {
        out[j] = dot_q8(simd, w.row(j), x_q) as f32 * w.scale(j) * x_scale;
    }
}

/// Dense GEMV over every row (equivalent to `masked_gemv` with the
/// full row list, without materializing it).
pub fn dense_gemv(
    simd: Simd,
    w: &QuantMatrix,
    x_q: &[i8],
    x_scale: f32,
    out: &mut [f32],
) {
    for j in 0..w.rows() {
        out[j] = dot_q8(simd, w.row(j), x_q) as f32 * w.scale(j) * x_scale;
    }
}

/// Below this many row·col MACs a parallel dispatch costs more than it
/// saves; callers fall back to the sequential kernel.
pub const POOL_MIN_MACS: usize = 1 << 16;

/// Worker-pool masked GEMV: the kept-row list is split into contiguous
/// chunks, each computed on a pool worker; results return over a
/// channel and are scattered by the caller thread. Every `out[j]` is
/// computed by exactly one worker with the same arithmetic as
/// [`masked_gemv`], so the result is bit-identical to the sequential
/// path regardless of scheduling.
pub fn masked_gemv_pooled(
    simd: Simd,
    w: &QuantMatrix,
    x_q: &[i8],
    x_scale: f32,
    rows: &[usize],
    out: &mut [f32],
    pool: &ThreadPool,
    jobs: usize,
) {
    let jobs = jobs.max(1).min(rows.len());
    if jobs <= 1 || rows.len() * w.cols() < POOL_MIN_MACS {
        return masked_gemv(simd, w, x_q, x_scale, rows, out);
    }

    /// Read-only views shared with pool workers. Workers only READ
    /// through these pointers and return results over the channel.
    struct RawView {
        w: *const QuantMatrix,
        x: *const i8,
        x_len: usize,
        rows: *const usize,
        rows_len: usize,
    }
    // SAFETY: the dispatching call blocks on the result channel until
    // every job has replied (or dropped its sender), so the borrows
    // behind these pointers outlive all reads; workers never write.
    unsafe impl Send for RawView {}

    let chunk = rows.len().div_ceil(jobs);
    let n_jobs = rows.len().div_ceil(chunk);
    let (tx, rx) = mpsc::channel::<(usize, Vec<f32>)>();
    for ji in 0..n_jobs {
        let start = ji * chunk;
        let end = (start + chunk).min(rows.len());
        let view = RawView {
            w,
            x: x_q.as_ptr(),
            x_len: x_q.len(),
            rows: rows.as_ptr(),
            rows_len: rows.len(),
        };
        let tx = tx.clone();
        pool.execute(move || {
            // SAFETY: see the `unsafe impl Send for RawView` above —
            // the dispatching call blocks until this job replies, so
            // the views are live, and this job only reads them.
            let (w, x, rows) = unsafe {
                (
                    &*view.w,
                    std::slice::from_raw_parts(view.x, view.x_len),
                    std::slice::from_raw_parts(view.rows, view.rows_len),
                )
            };
            let mut vals = Vec::with_capacity(end - start);
            for &j in &rows[start..end] {
                vals.push(
                    dot_q8(simd, w.row(j), x) as f32 * w.scale(j) * x_scale,
                );
            }
            let _ = tx.send((start, vals));
        });
    }
    drop(tx);
    let mut received = 0usize;
    while received < n_jobs {
        match rx.recv() {
            Ok((start, vals)) => {
                for (i, v) in vals.into_iter().enumerate() {
                    out[rows[start + i]] = v;
                }
                received += 1;
            }
            Err(_) => {
                // a worker died mid-call (poisoned pool): recompute the
                // whole call sequentially — correctness over speed
                masked_gemv(simd, w, x_q, x_scale, rows, out);
                return;
            }
        }
    }
}

/// Numerically stable SiLU (x · sigmoid(x)); plain f32 scalar math so
/// every path computes activations identically.
pub fn silu(x: f32) -> f32 {
    x / (1.0 + (-x).exp())
}

/// One masked FFN block over quantized weights:
/// `y += Σ_{j ∈ rows} silu(gate_j)·up_j · down[j, :]`, where
/// `up_j = dot(up.row(j), x)·scales` and likewise for `gate_j`.
/// Only the listed unit rows of `up`, `gate`, AND `down` are loaded.
/// When `acts` is provided, the dequantized per-unit activation
/// `silu(gate_j)·up_j` is written to `acts[j]` (the GLASS importance
/// tap). Returns the number of unit rows visited.
#[allow(clippy::too_many_arguments)]
pub fn ffn_forward_masked(
    simd: Simd,
    up: &QuantMatrix,
    gate: &QuantMatrix,
    down: &QuantMatrix,
    x_q: &[i8],
    x_scale: f32,
    rows: &[usize],
    y: &mut [f32],
    mut acts: Option<&mut [f32]>,
) -> usize {
    for &j in rows {
        let up_j = dot_q8(simd, up.row(j), x_q) as f32 * up.scale(j) * x_scale;
        let gate_j =
            dot_q8(simd, gate.row(j), x_q) as f32 * gate.scale(j) * x_scale;
        let a = silu(gate_j) * up_j;
        if let Some(acts) = acts.as_deref_mut() {
            acts[j] = a;
        }
        let ds = down.scale(j);
        let drow = down.row(j);
        let n = y.len().min(drow.len());
        for c in 0..n {
            y[c] += a * (drow[c] as f32 * ds);
        }
    }
    rows.len()
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Tiny deterministic generator (SplitMix64) for test matrices.
    struct Gen(u64);
    impl Gen {
        fn next_u64(&mut self) -> u64 {
            self.0 = self.0.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = self.0;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        }
        fn f32(&mut self) -> f32 {
            (self.next_u64() >> 40) as f32 / (1u64 << 24) as f32 * 2.0 - 1.0
        }
    }

    #[test]
    fn round_trip_error_bounded_by_row_scale() {
        // Property: per-row symmetric quantization reconstructs every
        // element to within half a quantization step (scale/2).
        let mut g = Gen(7);
        for case in 0..50 {
            let cols = 1 + (g.next_u64() as usize % 96);
            let amp = 0.01 + (case as f32) * 0.37;
            let src: Vec<f32> =
                (0..cols).map(|_| g.f32() * amp).collect();
            let (q, scale) = quantize_row(&src);
            assert!(scale > 0.0);
            for (i, &x) in src.iter().enumerate() {
                let deq = q[i] as f32 * scale;
                assert!(
                    (deq - x).abs() <= scale * 0.5 + 1e-9,
                    "case {case} col {i}: |{deq} - {x}| > {}",
                    scale * 0.5
                );
            }
        }
        // all-zero rows stay representable (scale 1.0, zero codes)
        let (q, s) = quantize_row(&[0.0; 8]);
        assert_eq!(s, 1.0);
        assert!(q.iter().all(|&v| v == 0));
    }

    #[test]
    fn masked_equals_dense_then_zero_on_every_simd_path() {
        // masked GEMV == dense GEMV with non-kept rows zeroed, and all
        // runnable SIMD paths agree with the scalar one bit for bit.
        let mut g = Gen(11);
        for trial in 0..8 {
            let rows = 8 + (g.next_u64() as usize % 120);
            let cols = 1 + (g.next_u64() as usize % 200);
            let src: Vec<f32> =
                (0..rows * cols).map(|_| g.f32()).collect();
            let w = QuantMatrix::from_rows(rows, cols, &src).unwrap();
            let x: Vec<f32> = (0..cols).map(|_| g.f32()).collect();
            let (xq, xs) = quantize_row(&x);
            let kept: Vec<usize> =
                (0..rows).filter(|j| j % 3 != trial % 3).collect();

            let mut dense_ref = vec![0.0f32; rows];
            dense_gemv(Simd::Scalar, &w, &xq, xs, &mut dense_ref);
            let mut expect = dense_ref.clone();
            for j in 0..rows {
                if !kept.contains(&j) {
                    expect[j] = 0.0;
                }
            }
            for simd in available() {
                let mut out = vec![0.0f32; rows];
                masked_gemv(simd, &w, &xq, xs, &kept, &mut out);
                assert_eq!(
                    out.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                    expect.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                    "path {} diverged (trial {trial})",
                    simd.label()
                );
            }
        }
    }

    #[test]
    fn pooled_gemv_bit_identical_to_sequential() {
        let mut g = Gen(23);
        let (rows, cols) = (512, 256); // above POOL_MIN_MACS
        let src: Vec<f32> = (0..rows * cols).map(|_| g.f32()).collect();
        let w = QuantMatrix::from_rows(rows, cols, &src).unwrap();
        let x: Vec<f32> = (0..cols).map(|_| g.f32()).collect();
        let (xq, xs) = quantize_row(&x);
        let kept: Vec<usize> = (0..rows).filter(|j| j % 2 == 0).collect();
        let simd = detect();
        let mut seq = vec![0.0f32; rows];
        masked_gemv(simd, &w, &xq, xs, &kept, &mut seq);
        let pool = ThreadPool::new(4);
        let mut par = vec![0.0f32; rows];
        masked_gemv_pooled(simd, &w, &xq, xs, &kept, &mut par, &pool, 4);
        assert_eq!(
            seq.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            par.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn transpose_construction_matches_direct() {
        let src = [1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0]; // 2x3
        let t = QuantMatrix::from_columns(2, 3, &src).unwrap(); // 3x2
        let direct =
            QuantMatrix::from_rows(3, 2, &[1.0, 4.0, 2.0, 5.0, 3.0, 6.0])
                .unwrap();
        for r in 0..3 {
            assert_eq!(t.row(r), direct.row(r));
            assert_eq!(t.scale(r).to_bits(), direct.scale(r).to_bits());
        }
    }

    #[test]
    fn poisoned_row_propagates_nan_only_when_read() {
        let src = [0.5f32, -0.25, 0.125, 1.0, 0.75, -0.5];
        let mut w = QuantMatrix::from_rows(3, 2, &src).unwrap();
        w.poison_row(1);
        let (xq, xs) = quantize_row(&[1.0, 1.0]);
        let simd = detect();
        let mut out = vec![0.0f32; 3];
        masked_gemv(simd, &w, &xq, xs, &[0, 2], &mut out);
        assert!(out.iter().all(|v| v.is_finite()), "skipped row was read");
        masked_gemv(simd, &w, &xq, xs, &[0, 1, 2], &mut out);
        assert!(out[1].is_nan(), "poisoned row read must surface NaN");
    }

    #[test]
    fn ffn_forward_skips_unlisted_units() {
        let mut g = Gen(41);
        let (m, d) = (16, 8);
        let mk = |g: &mut Gen| {
            let v: Vec<f32> = (0..m * d).map(|_| g.f32()).collect();
            QuantMatrix::from_rows(m, d, &v).unwrap()
        };
        let (up, gate, down) = (mk(&mut g), mk(&mut g), mk(&mut g));
        let x: Vec<f32> = (0..d).map(|_| g.f32()).collect();
        let (xq, xs) = quantize_row(&x);
        let kept: Vec<usize> = (0..m / 2).collect();
        // poison every non-kept unit in all three projections
        let mut up_p = up.clone();
        let mut gate_p = gate.clone();
        let mut down_p = down.clone();
        for j in m / 2..m {
            up_p.poison_row(j);
            gate_p.poison_row(j);
            down_p.poison_row(j);
        }
        let simd = detect();
        let mut y_clean = vec![0.0f32; d];
        let mut acts = vec![0.0f32; m];
        ffn_forward_masked(
            simd, &up, &gate, &down, &xq, xs, &kept, &mut y_clean,
            Some(&mut acts),
        );
        let mut y_poison = vec![0.0f32; d];
        let visited = ffn_forward_masked(
            simd, &up_p, &gate_p, &down_p, &xq, xs, &kept, &mut y_poison,
            None,
        );
        assert_eq!(visited, kept.len());
        assert_eq!(
            y_clean.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            y_poison.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            "poisoned masked-out units leaked into the FFN output"
        );
        assert!(acts[..m / 2].iter().any(|&a| a != 0.0));
        assert!(acts[m / 2..].iter().all(|&a| a == 0.0));
    }
}
