//! Deterministic simulator backend: a pure-Rust stand-in for the AOT
//! executables, with the same calling contract as the PJRT backend.
//!
//! The build image cannot always run the real XLA artifacts (the
//! `xla_extension` C++ runtime and the python AOT step are unavailable
//! offline), so this backend implements the executable contract —
//! prefill / masked decode step / gathered top-k decode / fused generate
//! / teacher-forced score — as a closed-form "toy transformer" whose
//! behavior is analytically controlled:
//!
//! * **Grammar head.** Each vocab token has one strongly preferred
//!   successor (an alphabet walk with spaces), scaled by the kept-mask
//!   FFN "strength". The dense model confidently follows the grammar;
//!   heavily pruned models fall into deterministic hash noise.
//! * **Neuron importance.** FFN unit `j` carries geometric weight
//!   `1.5·0.7^j`; mask strength is the product over layers of kept
//!   weight mass. Informed top-k masks keep ≈ all mass, random masks
//!   don't — reproducing the paper's quality ordering (dense ≥ GLASS ≈
//!   GRIFFIN ≫ random) and the KLD-vs-density monotone.
//! * **Decode-time drift.** During decode, units in alternating
//!   sign-blocks of four are boosted/suppressed (±Δ), so decode-time
//!   statistics *drift away* from prompt statistics. This is what makes
//!   a mid-generation GLASS mask refresh change the kept set — the
//!   long-form scenario in the paper's motivation — and makes the
//!   post-hoc oracle (ranked by true decode weights) at least as good
//!   as prompt-only GRIFFIN.
//!
//! Everything is a pure function of (token, position, layer, unit) via
//! SplitMix64 hashing: batch slots are exactly independent, fused and
//! step decode agree bitwise, and runs are reproducible.

use std::path::PathBuf;

use anyhow::{bail, Result};

use super::manifest::{DType, ExeSpec, IoSpec, Manifest, ModelSpec, ParamSpec};
use super::Value;
use crate::tensor::{argmax, TensorF, TensorI};

// ------------------------------------------------------------ constants

/// Top neuron weight; unit j carries GAIN·RATIO^j.
const GAIN: f64 = 1.5;
const RATIO: f64 = 0.7;
/// Decode-time drift amplitude (±) applied in sign-blocks of four.
const DELTA: f64 = 0.5;
/// Per-(token,position) jitter on statistics.
const EPS: f64 = 0.05;
/// Grammar-head logit margin at full strength.
const MARGIN: f64 = 8.0;
/// Hash-noise amplitude on all logits.
const NOISE: f64 = 1.5;

const SALT_NOISE: u64 = 0x9E00;
const SALT_PROMPT: u64 = 0x51;
const SALT_DEC: u64 = 0x52;
const SALT_PRIOR: u64 = 0x53;
const SALT_KV: u64 = 0x54;
const SALT_PARAM: u64 = 0x55;

// -------------------------------------------------------------- hashing

fn sm64(mut z: u64) -> u64 {
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

fn hmix(vals: &[u64]) -> u64 {
    let mut h: u64 = 0x243F6A8885A308D3;
    for &v in vals {
        h = sm64(h ^ sm64(v));
    }
    h
}

/// Deterministic uniform value in [0, 1). Shared with the `cpu_q8`
/// backend so both derive jitter from the same hash family.
pub(crate) fn h01(vals: &[u64]) -> f64 {
    (hmix(vals) >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
}

// ---------------------------------------------------------- toy model

/// The bigram grammar: lowercase alphabet walk with a space after 'z';
/// anything else re-enters the alphabet deterministically.
fn next_byte(t: i32) -> i32 {
    match t {
        97..=121 => t + 1,
        122 => 32,
        32 => 97,
        _ => 97 + t.rem_euclid(26),
    }
}

/// Decode-drift sign for unit j: blocks of two boosted, two suppressed.
/// The block-of-4 period moves drifted units by TWO local rank positions
/// at kept-set boundaries, enough to flip λ=0.5 rank fusion too.
fn drift_sign(j: usize) -> f64 {
    if j % 4 < 2 {
        1.0
    } else {
        -1.0
    }
}

pub(crate) fn l2_normalize(v: &mut [f64]) {
    let n = v.iter().map(|x| x * x).sum::<f64>().sqrt();
    if n > 0.0 {
        for x in v.iter_mut() {
            *x /= n;
        }
    }
}

/// The simulator backend; cheap, immutable, thread-safe. Fields are
/// crate-visible because the `cpu_q8` backend reuses the closed-form
/// head (logits strength, KV rows) while replacing the FFN/importance
/// compute with real quantized GEMVs.
pub struct SimBackend {
    pub(crate) spec: ModelSpec,
    /// gain[j] = GAIN·RATIO^j.
    pub(crate) gain: Vec<f64>,
    /// Decode-time unit weights gain[j]·(1 + Δ·sign(j)) and their sum.
    pub(crate) w_dec: Vec<f64>,
    w_dec_sum: f64,
}

impl SimBackend {
    pub fn new(spec: ModelSpec) -> SimBackend {
        let m = spec.ffn_m;
        let gain: Vec<f64> = (0..m).map(|j| GAIN * RATIO.powi(j as i32)).collect();
        let w_dec: Vec<f64> = (0..m)
            .map(|j| gain[j] * (1.0 + DELTA * drift_sign(j)))
            .collect();
        let w_dec_sum = w_dec.iter().sum();
        SimBackend {
            spec,
            gain,
            w_dec,
            w_dec_sum,
        }
    }

    // ------------------------------------------------------- primitives

    /// FFN strength of a mask: product over layers of kept decode-weight
    /// mass fraction. 1.0 for dense, → 0 as important units are dropped.
    pub(crate) fn strength(&self, kept: &[Vec<usize>]) -> f64 {
        let mut s = 1.0;
        for layer in kept {
            let mass: f64 = layer.iter().map(|&j| self.w_dec[j]).sum();
            s *= mass / self.w_dec_sum;
        }
        s
    }

    /// Next-token logits after consuming `t` under FFN strength `s`.
    /// Shared by prefill, step decode, fused generate and score, so all
    /// paths agree bitwise.
    pub(crate) fn step_logits(&self, t: i32, s: f64) -> Vec<f32> {
        let v = self.spec.vocab;
        let mut row: Vec<f64> = (0..v)
            .map(|tok| NOISE * h01(&[SALT_NOISE, t as u64, tok as u64]))
            .collect();
        let nx = next_byte(t) as usize;
        if nx < v {
            row[nx] += MARGIN * s;
        }
        row.into_iter().map(|x| x as f32).collect()
    }

    /// Per-token prompt statistics for one layer (ℓ2-normalized).
    fn prompt_tok_stats(&self, t: i32, l: usize) -> Vec<f64> {
        let mut v: Vec<f64> = (0..self.spec.ffn_m)
            .map(|j| {
                let jitter =
                    2.0 * h01(&[SALT_PROMPT, t as u64, l as u64, j as u64]) - 1.0;
                self.gain[j] * (1.0 + EPS * jitter)
            })
            .collect();
        l2_normalize(&mut v);
        v
    }

    /// Per-token decode statistics for one layer (ℓ2-normalized) —
    /// carries the ±Δ drift that distinguishes decode from prompt time.
    fn dec_tok_stats(&self, t: i32, p: i32, l: usize) -> Vec<f64> {
        let mut v: Vec<f64> = (0..self.spec.ffn_m)
            .map(|j| {
                let jitter = 2.0
                    * h01(&[SALT_DEC, t as u64, p as u64, l as u64, j as u64])
                    - 1.0;
                self.w_dec[j] * (1.0 + EPS * jitter)
            })
            .collect();
        l2_normalize(&mut v);
        v
    }

    fn kv_value(&self, tag: u64, t: i32, p: i32, l: usize, h: usize, e: usize) -> f32 {
        (h01(&[SALT_KV, tag, t as u64, p as u64, l as u64, h as u64, e as u64])
            - 0.5) as f32
    }

    /// Write the KV row for (token t, position p) into [L,B,H,T,Dh] data.
    pub(crate) fn write_kv_row(
        &self,
        k: &mut [f32],
        v: &mut [f32],
        b: usize,
        slot: usize,
        t: i32,
        p: i32,
    ) {
        let spec = &self.spec;
        let (hn, tn, dh) = (spec.n_heads, spec.max_seq, spec.head_dim);
        if p < 0 || p as usize >= tn {
            return;
        }
        for l in 0..spec.n_layers {
            for h in 0..hn {
                let base = ((((l * b + slot) * hn) + h) * tn + p as usize) * dh;
                for e in 0..dh {
                    k[base + e] = self.kv_value(0, t, p, l, h, e);
                    v[base + e] = self.kv_value(1, t, p, l, h, e);
                }
            }
        }
    }

    /// Kept unit ids per layer from one slot's [L, m] mask values.
    pub(crate) fn kept_from_mask(
        &self,
        mask: &TensorF,
        slot: usize,
    ) -> Vec<Vec<usize>> {
        let (l_n, m) = (self.spec.n_layers, self.spec.ffn_m);
        (0..l_n)
            .map(|l| {
                let base = (slot * l_n + l) * m;
                (0..m)
                    .filter(|&j| mask.data[base + j] > 0.5)
                    .collect::<Vec<usize>>()
            })
            .collect()
    }

    pub(crate) fn kept_from_idx(
        &self,
        idx: &TensorI,
        slot: usize,
    ) -> Vec<Vec<usize>> {
        let l_n = self.spec.n_layers;
        let k = idx.shape[2];
        (0..l_n)
            .map(|l| {
                let base = (slot * l_n + l) * k;
                idx.data[base..base + k]
                    .iter()
                    .map(|&j| j as usize)
                    .collect::<Vec<usize>>()
            })
            .collect()
    }

    /// Global prior map for a named prior ([L][m], ℓ2-normalized rows).
    pub fn prior(&self, name: &str) -> Result<Vec<Vec<f32>>> {
        let kind: u64 = match name {
            "a_nps" => 0,
            "i_nps" => 1,
            "a_corpus" => 2,
            "i_corpus" => 3,
            other => bail!("sim backend has no prior '{other}'"),
        };
        let spec = &self.spec;
        Ok((0..spec.n_layers)
            .map(|l| {
                let mut v: Vec<f64> = (0..spec.ffn_m)
                    .map(|j| {
                        let jitter = 2.0
                            * h01(&[SALT_PRIOR, kind, l as u64, j as u64])
                            - 1.0;
                        self.gain[j] * (1.0 + EPS * jitter)
                    })
                    .collect();
                l2_normalize(&mut v);
                v.into_iter().map(|x| x as f32).collect()
            })
            .collect())
    }

    /// Deterministic host weights for the synthetic param store.
    pub fn param_values(name: &str, numel: usize) -> Vec<f32> {
        let tag = hmix(&[SALT_PARAM, name.len() as u64])
            ^ name.bytes().fold(0u64, |a, b| sm64(a ^ b as u64));
        (0..numel)
            .map(|i| (h01(&[SALT_PARAM, tag, i as u64]) as f32 - 0.5) * 0.2)
            .collect()
    }

    // ------------------------------------------------------ executables

    /// Execute an executable by manifest name (operands pre-validated
    /// against the ExeSpec by the runtime).
    pub fn call(&self, name: &str, operands: &[Value]) -> Result<Vec<Value>> {
        let (kind, b) = parse_exe_name(name)
            .ok_or_else(|| anyhow::anyhow!("sim backend: bad exe name '{name}'"))?;
        match kind {
            "prefill" => self.run_prefill(b, operands),
            "prefill_chunk" => self.run_prefill_chunk(b, operands),
            "decode" => self.run_decode(b, operands, false),
            "decode_topk" => self.run_decode(b, operands, true),
            "score" => self.run_score(b, operands),
            "generate" => self.run_generate(b, operands),
            other => bail!("sim backend: unknown executable kind '{other}'"),
        }
    }

    fn run_prefill(&self, b: usize, operands: &[Value]) -> Result<Vec<Value>> {
        let spec = self.spec.clone();
        let tokens = operands[0].as_i32()?;
        let lens = operands[1].as_i32()?;
        let s_pre = spec.prefill_len;

        let mut logits = vec![0.0f32; b * spec.vocab];
        let kv_numel =
            spec.n_layers * b * spec.n_heads * spec.max_seq * spec.head_dim;
        let mut k = vec![0.0f32; kv_numel];
        let mut v = vec![0.0f32; kv_numel];
        let mut stats = vec![0.0f32; b * spec.n_layers * spec.ffn_m];

        for slot in 0..b {
            let len = (lens.data[slot].max(1) as usize).min(s_pre);
            let toks = &tokens.data[slot * s_pre..(slot + 1) * s_pre];
            // next-token logits at the last real position, dense strength
            let row = self.step_logits(toks[len - 1], 1.0);
            logits[slot * spec.vocab..(slot + 1) * spec.vocab]
                .copy_from_slice(&row);
            // KV for every prefill frame position (pad rows are
            // overwritten by decode before they can be attended)
            for (p, &t) in toks.iter().enumerate() {
                self.write_kv_row(&mut k, &mut v, b, slot, t, p as i32);
            }
            // local statistics A^l: mean of per-token prompt stats
            for l in 0..spec.n_layers {
                let base = (slot * spec.n_layers + l) * spec.ffn_m;
                for &t in toks.iter().take(len) {
                    let st = self.prompt_tok_stats(t, l);
                    for j in 0..spec.ffn_m {
                        stats[base + j] += (st[j] / len as f64) as f32;
                    }
                }
            }
        }
        Ok(vec![
            Value::F32(TensorF::new(vec![b, spec.vocab], logits)?),
            Value::F32(TensorF::new(
                vec![spec.n_layers, b, spec.n_heads, spec.max_seq, spec.head_dim],
                k,
            )?),
            Value::F32(TensorF::new(
                vec![spec.n_layers, b, spec.n_heads, spec.max_seq, spec.head_dim],
                v,
            )?),
            Value::F32(TensorF::new(
                vec![b, spec.n_layers, spec.ffn_m],
                stats,
            )?),
        ])
    }

    /// One chunk of a chunked prefill: consume up to `prefill_len` prompt
    /// tokens starting at an absolute sequence offset, appending KV rows
    /// at `offset + p` into the carried-in cache and emitting *per-chunk*
    /// local statistics (mean over this chunk's valid tokens only — the
    /// host merges chunks via `ImportanceMap::merge`). For a prompt that
    /// fits one frame (offset 0, len == prompt len) the logits and stats
    /// are bit-identical to the monolithic `prefill` executable; KV rows
    /// are written only for the chunk's valid tokens (no trailing PAD
    /// rows — those are decode-overwritten scratch in the monolithic
    /// path and carry no information).
    fn run_prefill_chunk(
        &self,
        b: usize,
        operands: &[Value],
    ) -> Result<Vec<Value>> {
        let spec = self.spec.clone();
        let tokens = operands[0].as_i32()?;
        let lens = operands[1].as_i32()?;
        let offsets = operands[2].as_i32()?;
        let mut k = operands[3].as_f32()?.clone();
        let mut v = operands[4].as_f32()?.clone();
        let s_pre = spec.prefill_len;

        let mut logits = vec![0.0f32; b * spec.vocab];
        let mut stats = vec![0.0f32; b * spec.n_layers * spec.ffn_m];
        for slot in 0..b {
            // len == 0 marks an idle slot in this chunk call: no KV
            // writes, zero stats, logits left at zero (caller ignores)
            let len = (lens.data[slot].max(0) as usize).min(s_pre);
            if len == 0 {
                continue;
            }
            let off = offsets.data[slot].max(0);
            let toks = &tokens.data[slot * s_pre..slot * s_pre + len];
            let row = self.step_logits(toks[len - 1], 1.0);
            logits[slot * spec.vocab..(slot + 1) * spec.vocab]
                .copy_from_slice(&row);
            for (p, &t) in toks.iter().enumerate() {
                self.write_kv_row(
                    &mut k.data,
                    &mut v.data,
                    b,
                    slot,
                    t,
                    off + p as i32,
                );
            }
            // same accumulation order/arithmetic as run_prefill so a
            // single-chunk call reproduces its stats bit-for-bit
            for l in 0..spec.n_layers {
                let base = (slot * spec.n_layers + l) * spec.ffn_m;
                for &t in toks {
                    let st = self.prompt_tok_stats(t, l);
                    for j in 0..spec.ffn_m {
                        stats[base + j] += (st[j] / len as f64) as f32;
                    }
                }
            }
        }
        Ok(vec![
            Value::F32(TensorF::new(vec![b, spec.vocab], logits)?),
            Value::F32(k),
            Value::F32(v),
            Value::F32(TensorF::new(
                vec![b, spec.n_layers, spec.ffn_m],
                stats,
            )?),
        ])
    }

    fn run_decode(
        &self,
        b: usize,
        operands: &[Value],
        gathered: bool,
    ) -> Result<Vec<Value>> {
        let spec = self.spec.clone();
        let tokens = operands[0].as_i32()?;
        let pos = operands[1].as_i32()?;
        let mut k = operands[2].as_f32()?.clone();
        let mut v = operands[3].as_f32()?.clone();

        let mut logits = vec![0.0f32; b * spec.vocab];
        let mut stats = vec![0.0f32; b * spec.n_layers * spec.ffn_m];
        for slot in 0..b {
            let kept = if gathered {
                self.kept_from_idx(operands[4].as_i32()?, slot)
            } else {
                self.kept_from_mask(operands[4].as_f32()?, slot)
            };
            let t = tokens.data[slot];
            let p = pos.data[slot];
            let row = self.step_logits(t, self.strength(&kept));
            logits[slot * spec.vocab..(slot + 1) * spec.vocab]
                .copy_from_slice(&row);
            self.write_kv_row(&mut k.data, &mut v.data, b, slot, t, p);
            for l in 0..spec.n_layers {
                let st = self.dec_tok_stats(t, p, l);
                let base = (slot * spec.n_layers + l) * spec.ffn_m;
                for j in 0..spec.ffn_m {
                    stats[base + j] = st[j] as f32;
                }
            }
        }
        Ok(vec![
            Value::F32(TensorF::new(vec![b, spec.vocab], logits)?),
            Value::F32(k),
            Value::F32(v),
            Value::F32(TensorF::new(
                vec![b, spec.n_layers, spec.ffn_m],
                stats,
            )?),
        ])
    }

    fn run_score(&self, b: usize, operands: &[Value]) -> Result<Vec<Value>> {
        let spec = self.spec.clone();
        let tokens = operands[0].as_i32()?;
        let weights = operands[1].as_f32()?;
        let mask = operands[2].as_f32()?;
        let s_len = spec.score_len;

        let mut logits = vec![0.0f32; b * s_len * spec.vocab];
        let mut stats = vec![0.0f32; b * spec.n_layers * spec.ffn_m];
        for slot in 0..b {
            let kept = self.kept_from_mask(mask, slot);
            let s = self.strength(&kept);
            let mut w_total = 0.0f64;
            let mut acc =
                vec![vec![0.0f64; spec.ffn_m]; spec.n_layers];
            for p in 0..s_len {
                let t = tokens.data[slot * s_len + p];
                let row = self.step_logits(t, s);
                let base = (slot * s_len + p) * spec.vocab;
                logits[base..base + spec.vocab].copy_from_slice(&row);
                let w = weights.data[slot * s_len + p] as f64;
                if w > 0.0 {
                    w_total += w;
                    for l in 0..spec.n_layers {
                        let st = self.dec_tok_stats(t, p as i32, l);
                        for j in 0..spec.ffn_m {
                            acc[l][j] += w * st[j];
                        }
                    }
                }
            }
            if w_total > 0.0 {
                for l in 0..spec.n_layers {
                    let base = (slot * spec.n_layers + l) * spec.ffn_m;
                    for j in 0..spec.ffn_m {
                        stats[base + j] = (acc[l][j] / w_total) as f32;
                    }
                }
            }
        }
        Ok(vec![
            Value::F32(TensorF::new(vec![b, s_len, spec.vocab], logits)?),
            Value::F32(TensorF::new(
                vec![b, spec.n_layers, spec.ffn_m],
                stats,
            )?),
        ])
    }

    fn run_generate(&self, b: usize, operands: &[Value]) -> Result<Vec<Value>> {
        let spec = self.spec.clone();
        let tokens = operands[0].as_i32()?;
        let lens = operands[1].as_i32()?;
        let mask = operands[2].as_f32()?;
        let s_pre = spec.prefill_len;
        let n = spec.gen_len;

        let mut out_toks = vec![0i32; b * n];
        let mut out_logits = vec![0.0f32; b * n * spec.vocab];
        let mut stats = vec![0.0f32; b * spec.n_layers * spec.ffn_m];
        for slot in 0..b {
            let kept = self.kept_from_mask(mask, slot);
            let s = self.strength(&kept);
            let len = (lens.data[slot].max(1) as usize).min(s_pre);
            let last = tokens.data[slot * s_pre + len - 1];
            // first generated token from the (masked) prefill position
            let mut tok = argmax(&self.step_logits(last, s)) as i32;
            for i in 0..n {
                out_toks[slot * n + i] = tok;
                let p = (len + i) as i32;
                for l in 0..spec.n_layers {
                    let st = self.dec_tok_stats(tok, p, l);
                    let base = (slot * spec.n_layers + l) * spec.ffn_m;
                    for j in 0..spec.ffn_m {
                        stats[base + j] += (st[j] / n as f64) as f32;
                    }
                }
                let row = self.step_logits(tok, s);
                let base = (slot * n + i) * spec.vocab;
                out_logits[base..base + spec.vocab].copy_from_slice(&row);
                if i + 1 < n {
                    tok = argmax(&row) as i32;
                }
            }
        }
        Ok(vec![
            Value::I32(TensorI::new(vec![b, n], out_toks)?),
            Value::F32(TensorF::new(vec![b, n, spec.vocab], out_logits)?),
            Value::F32(TensorF::new(
                vec![b, spec.n_layers, spec.ffn_m],
                stats,
            )?),
        ])
    }
}

impl super::ExecBackend for SimBackend {
    fn name(&self) -> &'static str {
        "sim"
    }

    fn capabilities(&self) -> super::Capabilities {
        super::Capabilities {
            native_masked_ffn: false,
            chunked_prefill: true,
            needs_warmup: false,
            deterministic: true,
        }
    }

    fn compile(&self, manifest: &Manifest, name: &str) -> Result<()> {
        // nothing to compile; validating the name is the whole warm-up
        manifest.exe(name).map(|_| ())
    }

    fn call(
        &self,
        _manifest: &Manifest,
        spec: &ExeSpec,
        operands: &[Value],
    ) -> Result<Vec<Value>> {
        let _t = crate::util::timer::global().start("runtime.execute");
        SimBackend::call(self, &spec.name, operands)
    }

    fn prior(&self, name: &str) -> Option<Result<Vec<Vec<f32>>>> {
        Some(SimBackend::prior(self, name))
    }
}

pub(crate) fn parse_exe_name(name: &str) -> Option<(&str, usize)> {
    let (kind, b) = name.rsplit_once("_b")?;
    Some((kind, b.parse().ok()?))
}

// --------------------------------------------------- synthetic bundle

/// The synthetic model spec used when no artifact bundle is available.
pub fn synthetic_spec() -> ModelSpec {
    ModelSpec {
        vocab: 260,
        d_model: 16,
        n_layers: 4,
        n_heads: 2,
        head_dim: 8,
        ffn_m: 32,
        // large enough that a multi-chunk prompt (several prefill_len
        // frames) still leaves decode room inside the KV window
        max_seq: 192,
        prefill_len: 32,
        score_len: 64,
        gen_len: 24,
        bos_id: 256,
        pad_id: 257,
    }
}

/// Batch sizes the synthetic bundle "compiles".
pub const SYNTHETIC_BATCH_SIZES: [usize; 4] = [1, 2, 4, 8];

/// Build an in-memory manifest equivalent to what `make artifacts`
/// produces, so every manifest-driven code path (batch discovery, shape
/// validation, weight footprint, priors) works without files on disk.
pub fn synthetic_manifest() -> Manifest {
    let spec = synthetic_spec();
    let io = |name: &str, shape: Vec<usize>, dtype: DType| IoSpec {
        name: name.to_string(),
        shape,
        dtype,
    };
    let kv_shape = |b: usize| {
        vec![spec.n_layers, b, spec.n_heads, spec.max_seq, spec.head_dim]
    };
    let mask_shape = |b: usize| vec![b, spec.n_layers, spec.ffn_m];
    let topk_k = spec.ffn_m / 2;

    let mut executables = Vec::new();
    for &b in &SYNTHETIC_BATCH_SIZES {
        executables.push(ExeSpec {
            name: format!("prefill_b{b}"),
            file: String::new(),
            n_params: 0,
            operands: vec![
                io("tokens", vec![b, spec.prefill_len], DType::I32),
                io("lens", vec![b], DType::I32),
            ],
            outputs: vec![
                io("logits", vec![b, spec.vocab], DType::F32),
                io("k", kv_shape(b), DType::F32),
                io("v", kv_shape(b), DType::F32),
                io("stats", mask_shape(b), DType::F32),
            ],
        });
        executables.push(ExeSpec {
            name: format!("prefill_chunk_b{b}"),
            file: String::new(),
            n_params: 0,
            operands: vec![
                io("tokens", vec![b, spec.prefill_len], DType::I32),
                io("lens", vec![b], DType::I32),
                io("offsets", vec![b], DType::I32),
                io("k", kv_shape(b), DType::F32),
                io("v", kv_shape(b), DType::F32),
            ],
            outputs: vec![
                io("logits", vec![b, spec.vocab], DType::F32),
                io("k", kv_shape(b), DType::F32),
                io("v", kv_shape(b), DType::F32),
                io("stats", mask_shape(b), DType::F32),
            ],
        });
        executables.push(ExeSpec {
            name: format!("decode_b{b}"),
            file: String::new(),
            n_params: 0,
            operands: vec![
                io("tokens", vec![b], DType::I32),
                io("pos", vec![b], DType::I32),
                io("k", kv_shape(b), DType::F32),
                io("v", kv_shape(b), DType::F32),
                io("mask", mask_shape(b), DType::F32),
            ],
            outputs: vec![
                io("logits", vec![b, spec.vocab], DType::F32),
                io("k", kv_shape(b), DType::F32),
                io("v", kv_shape(b), DType::F32),
                io("stats", mask_shape(b), DType::F32),
            ],
        });
        executables.push(ExeSpec {
            name: format!("decode_topk_b{b}"),
            file: String::new(),
            n_params: 0,
            operands: vec![
                io("tokens", vec![b], DType::I32),
                io("pos", vec![b], DType::I32),
                io("k", kv_shape(b), DType::F32),
                io("v", kv_shape(b), DType::F32),
                io("idx", vec![b, spec.n_layers, topk_k], DType::I32),
            ],
            outputs: vec![
                io("logits", vec![b, spec.vocab], DType::F32),
                io("k", kv_shape(b), DType::F32),
                io("v", kv_shape(b), DType::F32),
                io("stats", mask_shape(b), DType::F32),
            ],
        });
        executables.push(ExeSpec {
            name: format!("score_b{b}"),
            file: String::new(),
            n_params: 0,
            operands: vec![
                io("tokens", vec![b, spec.score_len], DType::I32),
                io("stats_w", vec![b, spec.score_len], DType::F32),
                io("mask", mask_shape(b), DType::F32),
            ],
            outputs: vec![
                io("logits", vec![b, spec.score_len, spec.vocab], DType::F32),
                io("stats", mask_shape(b), DType::F32),
            ],
        });
        executables.push(ExeSpec {
            name: format!("generate_b{b}"),
            file: String::new(),
            n_params: 0,
            operands: vec![
                io("tokens", vec![b, spec.prefill_len], DType::I32),
                io("lens", vec![b], DType::I32),
                io("mask", mask_shape(b), DType::F32),
            ],
            outputs: vec![
                io("tokens", vec![b, spec.gen_len], DType::I32),
                io("logits", vec![b, spec.gen_len, spec.vocab], DType::F32),
                io("stats", mask_shape(b), DType::F32),
            ],
        });
    }

    // weight inventory (for the memory simulator and `glass info`)
    let mut params = Vec::new();
    let mut offset = 0usize;
    let mut push = |params: &mut Vec<ParamSpec>, name: String, shape: Vec<usize>| {
        let numel: usize = shape.iter().product();
        params.push(ParamSpec {
            name,
            shape,
            offset,
            numel,
        });
        offset += numel * 4;
    };
    push(&mut params, "embed".into(), vec![spec.vocab, spec.d_model]);
    for l in 0..spec.n_layers {
        for w in ["wq", "wk", "wv", "wo"] {
            push(&mut params, format!("layer{l}.{w}"), vec![spec.d_model, spec.d_model]);
        }
        push(&mut params, format!("layer{l}.w_up"), vec![spec.d_model, spec.ffn_m]);
        push(&mut params, format!("layer{l}.w_gate"), vec![spec.d_model, spec.ffn_m]);
        push(&mut params, format!("layer{l}.w_down"), vec![spec.ffn_m, spec.d_model]);
    }
    push(&mut params, "head".into(), vec![spec.d_model, spec.vocab]);

    Manifest {
        dir: PathBuf::from("<synthetic>"),
        model: spec,
        topk_k,
        params_file: PathBuf::from("<synthetic>/params.bin"),
        params,
        executables,
        priors: vec![
            ("a_nps".into(), "<sim>".into()),
            ("i_nps".into(), "<sim>".into()),
            ("a_corpus".into(), "<sim>".into()),
            ("i_corpus".into(), "<sim>".into()),
        ],
        data: Vec::new(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn backend() -> SimBackend {
        SimBackend::new(synthetic_spec())
    }

    #[test]
    fn grammar_walks_alphabet_with_spaces() {
        assert_eq!(next_byte(b'a' as i32), b'b' as i32);
        assert_eq!(next_byte(b'z' as i32), b' ' as i32);
        assert_eq!(next_byte(b' ' as i32), b'a' as i32);
        // chain from any byte stays in printable ascii
        let mut t = 256;
        for _ in 0..60 {
            t = next_byte(t);
            assert!((32..127).contains(&t), "left ascii: {t}");
        }
    }

    #[test]
    fn h01_in_unit_interval_and_deterministic() {
        for i in 0..1000u64 {
            let x = h01(&[1, i]);
            assert!((0.0..1.0).contains(&x));
        }
        assert_eq!(h01(&[3, 4, 5]), h01(&[3, 4, 5]));
        assert_ne!(h01(&[3, 4, 5]), h01(&[3, 4, 6]));
    }

    #[test]
    fn strength_monotone_in_kept_mass() {
        let be = backend();
        let m = be.spec.ffn_m;
        let dense: Vec<Vec<usize>> =
            vec![(0..m).collect(); be.spec.n_layers];
        let top_half: Vec<Vec<usize>> =
            vec![(0..m / 2).collect(); be.spec.n_layers];
        let bottom_half: Vec<Vec<usize>> =
            vec![(m / 2..m).collect(); be.spec.n_layers];
        let s_dense = be.strength(&dense);
        let s_top = be.strength(&top_half);
        let s_bottom = be.strength(&bottom_half);
        assert!((s_dense - 1.0).abs() < 1e-12);
        assert!(s_top < s_dense && s_top > 0.9, "{s_top}");
        assert!(s_bottom < 0.01, "{s_bottom}");
    }

    #[test]
    fn dense_logits_follow_grammar() {
        let be = backend();
        let m = be.spec.ffn_m;
        let dense: Vec<Vec<usize>> =
            vec![(0..m).collect(); be.spec.n_layers];
        let row = be.step_logits(b'f' as i32, be.strength(&dense));
        assert_eq!(argmax(&row), b'g' as usize);
    }

    #[test]
    fn priors_distinct_and_normalized() {
        let be = backend();
        for name in ["a_nps", "i_nps", "a_corpus", "i_corpus"] {
            let p = be.prior(name).unwrap();
            assert_eq!(p.len(), be.spec.n_layers);
            let l0 = &p[0];
            assert!(l0.iter().any(|&x| (x - l0[0]).abs() > 1e-9));
            let norm: f32 = l0.iter().map(|x| x * x).sum::<f32>().sqrt();
            assert!((norm - 1.0).abs() < 1e-3);
        }
        assert!(be.prior("nope").is_err());
    }

    #[test]
    fn exe_name_parsing() {
        assert_eq!(parse_exe_name("prefill_b4"), Some(("prefill", 4)));
        assert_eq!(
            parse_exe_name("prefill_chunk_b1"),
            Some(("prefill_chunk", 1))
        );
        assert_eq!(
            parse_exe_name("decode_topk_b8"),
            Some(("decode_topk", 8))
        );
        assert_eq!(parse_exe_name("nope"), None);
    }

    #[test]
    fn prefill_chunk_outputs_ignore_unwritten_kv_contents() {
        // The shared-prefix cache splices stored rows into an otherwise
        // ZEROED KV window before resuming a stream, so the executable
        // contract it relies on is: a chunk's outputs (logits, stats,
        // and the rows it writes) are pure functions of (tokens,
        // offset) — rows it does not write pass through untouched and
        // are never read. The simulator must honor that bit for bit.
        let be = backend();
        let spec = synthetic_spec();
        let s = spec.prefill_len;
        let mut frame = vec![spec.pad_id; s];
        let toks = [97, 98, 99, 100, 101];
        frame[..toks.len()].copy_from_slice(&toks);
        let tokens = TensorI::new(vec![1, s], frame).unwrap();
        let lens = TensorI::new(vec![1], vec![toks.len() as i32]).unwrap();
        let off = 7i32;
        let offs = TensorI::new(vec![1], vec![off]).unwrap();
        let kv_shape = [
            spec.n_layers,
            1,
            spec.n_heads,
            spec.max_seq,
            spec.head_dim,
        ];
        let zeros = TensorF::zeros(&kv_shape);
        let mut junk = TensorF::zeros(&kv_shape);
        for x in junk.data.iter_mut() {
            *x = 9.875;
        }
        let run = |k: &TensorF, v: &TensorF| {
            be.call(
                "prefill_chunk_b1",
                &[
                    Value::I32(tokens.clone()),
                    Value::I32(lens.clone()),
                    Value::I32(offs.clone()),
                    Value::F32(k.clone()),
                    Value::F32(v.clone()),
                ],
            )
            .unwrap()
        };
        let a = run(&zeros, &zeros);
        let b = run(&junk, &junk);
        let f32s = |v: &Value| v.as_f32().unwrap().clone();
        assert_eq!(
            f32s(&a[0]).data,
            f32s(&b[0]).data,
            "logits depend on carried-in KV garbage"
        );
        assert_eq!(
            f32s(&a[3]).data,
            f32s(&b[3]).data,
            "stats depend on carried-in KV garbage"
        );
        // written rows identical; untouched rows pass through verbatim
        let (ka, kb) = (f32s(&a[1]), f32s(&b[1]));
        let (hn, tn, dh) = (spec.n_heads, spec.max_seq, spec.head_dim);
        for l in 0..spec.n_layers {
            for h in 0..hn {
                for p in 0..tn {
                    let base = ((l * hn + h) * tn + p) * dh;
                    let written = (p as i32) >= off
                        && (p as i32) < off + toks.len() as i32;
                    for e in 0..dh {
                        if written {
                            assert_eq!(
                                ka.data[base + e],
                                kb.data[base + e],
                                "written row differs l{l} h{h} p{p}"
                            );
                        } else {
                            assert_eq!(ka.data[base + e], 0.0);
                            assert_eq!(kb.data[base + e], 9.875);
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn synthetic_manifest_is_consistent() {
        let man = synthetic_manifest();
        assert_eq!(man.topk_k, man.model.ffn_m / 2);
        for kind in [
            "prefill",
            "prefill_chunk",
            "decode",
            "decode_topk",
            "score",
            "generate",
        ] {
            for b in SYNTHETIC_BATCH_SIZES {
                assert!(man.exe(&format!("{kind}_b{b}")).is_ok());
            }
        }
        assert!(!man.params.is_empty());
        let total: usize = man.params.iter().map(|p| p.numel * 4).sum();
        assert_eq!(man.params.last().unwrap().offset
            + man.params.last().unwrap().numel * 4, total);
    }
}
