//! Artifact manifest: the contract between `python/compile/aot.py` and the
//! Rust runtime. Parsed from `artifacts/manifest.json`.

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

impl DType {
    pub fn parse(s: &str) -> Result<DType> {
        match s {
            "f32" => Ok(DType::F32),
            "i32" => Ok(DType::I32),
            other => bail!("unknown dtype '{other}'"),
        }
    }

    pub fn size_bytes(self) -> usize {
        4
    }
}

/// One input/output slot of an executable.
#[derive(Debug, Clone)]
pub struct IoSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: DType,
}

impl IoSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }

    fn from_json(v: &Json) -> Result<IoSpec> {
        Ok(IoSpec {
            name: v.req("name")?.as_str()?.to_string(),
            shape: v.req("shape")?.usize_list()?,
            dtype: DType::parse(v.req("dtype")?.as_str()?)?,
        })
    }
}

/// One AOT-compiled program.
#[derive(Debug, Clone)]
pub struct ExeSpec {
    pub name: String,
    pub file: String,
    /// Model parameters come first in the HLO parameter list.
    pub n_params: usize,
    /// Operand slots (inputs AFTER the parameters).
    pub operands: Vec<IoSpec>,
    pub outputs: Vec<IoSpec>,
}

/// One tensor inside params.bin.
#[derive(Debug, Clone)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
    /// Byte offset in params.bin.
    pub offset: usize,
    pub numel: usize,
}

/// Model architecture constants (mirror of python ModelConfig).
#[derive(Debug, Clone)]
pub struct ModelSpec {
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub head_dim: usize,
    pub ffn_m: usize,
    pub max_seq: usize,
    pub prefill_len: usize,
    pub score_len: usize,
    pub gen_len: usize,
    pub bos_id: i32,
    pub pad_id: i32,
}

impl ModelSpec {
    fn from_json(v: &Json) -> Result<ModelSpec> {
        Ok(ModelSpec {
            vocab: v.req("vocab")?.as_usize()?,
            d_model: v.req("d_model")?.as_usize()?,
            n_layers: v.req("n_layers")?.as_usize()?,
            n_heads: v.req("n_heads")?.as_usize()?,
            head_dim: v.req("head_dim")?.as_usize()?,
            ffn_m: v.req("ffn_m")?.as_usize()?,
            max_seq: v.req("max_seq")?.as_usize()?,
            prefill_len: v.req("prefill_len")?.as_usize()?,
            score_len: v.req("score_len")?.as_usize()?,
            gen_len: v.req("gen_len")?.as_usize()?,
            bos_id: v.req("bos_id")?.as_i64()? as i32,
            pad_id: v.req("pad_id")?.as_i64()? as i32,
        })
    }

    /// Neuron budget k for a density in (0, 1].
    pub fn budget(&self, density: f64) -> usize {
        ((self.ffn_m as f64 * density).round() as usize)
            .clamp(1, self.ffn_m)
    }
}

#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub model: ModelSpec,
    pub topk_k: usize,
    pub params_file: PathBuf,
    pub params: Vec<ParamSpec>,
    pub executables: Vec<ExeSpec>,
    /// prior name -> relative path
    pub priors: Vec<(String, String)>,
    /// dataset name -> relative path
    pub data: Vec<(String, String)>,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let j = Json::parse_file(&path)
            .with_context(|| "loading artifact manifest (run `make artifacts`?)")?;
        let model = ModelSpec::from_json(j.req("model")?)?;

        let params = j
            .req("params")?
            .as_arr()?
            .iter()
            .map(|p| {
                Ok(ParamSpec {
                    name: p.req("name")?.as_str()?.to_string(),
                    shape: p.req("shape")?.usize_list()?,
                    offset: p.req("offset")?.as_usize()?,
                    numel: p.req("numel")?.as_usize()?,
                })
            })
            .collect::<Result<Vec<_>>>()?;

        let mut executables = Vec::new();
        for (name, e) in j.req("executables")?.as_obj()? {
            let n_params = e.req("n_params")?.as_usize()?;
            let all_inputs = e
                .req("inputs")?
                .as_arr()?
                .iter()
                .map(IoSpec::from_json)
                .collect::<Result<Vec<_>>>()?;
            if all_inputs.len() < n_params {
                bail!("exe {name}: inputs < n_params");
            }
            executables.push(ExeSpec {
                name: name.clone(),
                file: e.req("file")?.as_str()?.to_string(),
                n_params,
                operands: all_inputs[n_params..].to_vec(),
                outputs: e
                    .req("outputs")?
                    .as_arr()?
                    .iter()
                    .map(IoSpec::from_json)
                    .collect::<Result<Vec<_>>>()?,
            });
        }

        let pairs = |key: &str| -> Result<Vec<(String, String)>> {
            Ok(j.req(key)?
                .as_obj()?
                .iter()
                .map(|(k, v)| Ok((k.clone(), v.as_str()?.to_string())))
                .collect::<Result<Vec<_>>>()?)
        };

        Ok(Manifest {
            dir: dir.to_path_buf(),
            model,
            topk_k: j.req("topk_k")?.as_usize()?,
            params_file: dir.join(j.req("params_file")?.as_str()?),
            params,
            executables,
            priors: pairs("priors")?,
            data: pairs("data")?,
        })
    }

    pub fn exe(&self, name: &str) -> Result<&ExeSpec> {
        self.executables
            .iter()
            .find(|e| e.name == name)
            .ok_or_else(|| {
                anyhow::anyhow!(
                    "executable '{name}' not in manifest (have: {})",
                    self.executables
                        .iter()
                        .map(|e| e.name.as_str())
                        .collect::<Vec<_>>()
                        .join(", ")
                )
            })
    }

    pub fn prior_path(&self, name: &str) -> Result<PathBuf> {
        self.priors
            .iter()
            .find(|(k, _)| k == name)
            .map(|(_, v)| self.dir.join(v))
            .ok_or_else(|| anyhow::anyhow!("prior '{name}' not in manifest"))
    }

    pub fn data_path(&self, name: &str) -> Result<PathBuf> {
        self.data
            .iter()
            .find(|(k, _)| k == name)
            .map(|(_, v)| self.dir.join(v))
            .ok_or_else(|| anyhow::anyhow!("dataset '{name}' not in manifest"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_manifest() -> &'static str {
        r#"{
          "version": 1,
          "model": {"vocab":260,"d_model":8,"n_layers":2,"n_heads":2,
                    "head_dim":4,"ffn_m":16,"max_seq":8,"prefill_len":4,
                    "score_len":8,"gen_len":4,"rope_base":10000.0,
                    "bos_id":256,"pad_id":257},
          "topk_k": 8,
          "params_file": "params.bin",
          "params": [{"name":"embed","shape":[260,8],"offset":0,"numel":2080}],
          "executables": {
            "decode_b1": {
              "file": "decode_b1.hlo.txt",
              "n_params": 1,
              "inputs": [
                {"name":"embed","shape":[260,8],"dtype":"f32"},
                {"name":"token","shape":[1],"dtype":"i32"}
              ],
              "outputs": [{"name":"logits","shape":[1,260],"dtype":"f32"}]
            }
          },
          "priors": {"a_nps": "priors/a_nps.bin"},
          "data": {"lg": "data/lg.json"}
        }"#
    }

    #[test]
    fn parses_sample() {
        let dir = std::env::temp_dir().join("glass_manifest_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"), sample_manifest()).unwrap();
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.model.ffn_m, 16);
        assert_eq!(m.model.budget(0.5), 8);
        let e = m.exe("decode_b1").unwrap();
        assert_eq!(e.n_params, 1);
        assert_eq!(e.operands.len(), 1);
        assert_eq!(e.operands[0].name, "token");
        assert_eq!(e.operands[0].dtype, DType::I32);
        assert!(m.exe("nope").is_err());
        assert!(m.prior_path("a_nps").unwrap().ends_with("priors/a_nps.bin"));
    }

    #[test]
    fn budget_clamps() {
        let dir = std::env::temp_dir().join("glass_manifest_test2");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"), sample_manifest()).unwrap();
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.model.budget(0.0001), 1);
        assert_eq!(m.model.budget(1.0), 16);
    }
}
