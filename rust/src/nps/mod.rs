//! Null-Prompt Stimulation driver over the runtime (Sec. 3.3).
//!
//! The offline A^g prior ships with the artifact bundle (computed by
//! python/compile/nps.py at build time, like the paper's one-off
//! per-model precomputation). This module re-runs NPS **through the Rust
//! runtime** — BOS-only prefill, the App. B.3 sampling schedule, and
//! online accumulation of the decode stats — so the prior can be
//! regenerated or refreshed without Python, and so the two
//! implementations can be cross-checked (`glass nps --check`).

use anyhow::Result;

use crate::engine::Engine;
use crate::glass::{GlobalPrior, ImportanceMap, OnlineImportance};
use crate::model::NpsSampler;
use crate::util::prng::Prng;

#[derive(Debug, Clone)]
pub struct NpsConfig {
    pub n_seqs: usize,
    pub seq_len: usize,
    pub seed: u64,
}

impl Default for NpsConfig {
    fn default() -> Self {
        // scaled from the paper's 1000 × 1024 (Tab. 4) to model size
        NpsConfig {
            n_seqs: 16,
            seq_len: 96,
            seed: 42,
        }
    }
}

/// Outcome of a Rust-side NPS run.
#[derive(Debug, Clone)]
pub struct NpsRun {
    pub prior: GlobalPrior,
    pub n_tokens: u64,
    /// The generated stimulation text (diagnostics).
    pub samples: Vec<String>,
}

/// Run NPS with batch-1 step decoding and accumulate A^g online.
pub fn run_nps(engine: &Engine, cfg: &NpsConfig) -> Result<NpsRun> {
    let spec = engine.spec().clone();
    let mut acc = OnlineImportance::new(spec.n_layers, spec.ffn_m);
    let mut rng = Prng::new(cfg.seed);
    let mut samples = Vec::new();
    let max_steps = cfg.seq_len.min(spec.max_seq - 2);
    let mask = engine.dense_mask(1);

    for s in 0..cfg.n_seqs {
        // null prompt: BOS only
        let pre = engine.prefill(&[String::new()], 1)?;
        let mut kv = pre.kv;
        let mut sampler = NpsSampler::default();
        let mut seq_rng = rng.fork(s as u64);
        let mut tok = sampler.next(pre.logits.row(0), &mut seq_rng);
        let mut pos = pre.lens[0] as i32;
        let mut text_ids = vec![tok];

        for _ in 0..max_steps {
            let (logits, stats) =
                engine.decode_step(&mut kv, &[tok], &[pos], &mask)?;
            acc.push(&ImportanceMap::from_stats(&stats, 0)?);
            tok = sampler.next(logits.row(0), &mut seq_rng);
            text_ids.push(tok);
            pos += 1;
        }
        samples.push(engine.tok.decode(&text_ids));
    }

    let prior = GlobalPrior::new("a_nps_rust", acc.map.layers.clone())?;
    Ok(NpsRun {
        prior,
        n_tokens: acc.n_tokens,
        samples,
    })
}

/// Spearman correlation per layer between two priors — the cross-check
/// between the Rust-side NPS prior and the python build-time prior.
pub fn prior_agreement(a: &GlobalPrior, b: &GlobalPrior) -> Vec<f64> {
    use crate::util::stats::spearman;
    a.map
        .layers
        .iter()
        .zip(&b.map.layers)
        .map(|(x, y)| {
            let xs: Vec<f64> = x.iter().map(|v| *v as f64).collect();
            let ys: Vec<f64> = y.iter().map(|v| *v as f64).collect();
            spearman(&xs, &ys)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prior_agreement_self_is_one() {
        let p = GlobalPrior::new(
            "p",
            vec![vec![0.1, 0.5, 0.3], vec![0.9, 0.2, 0.4]],
        )
        .unwrap();
        let cors = prior_agreement(&p, &p);
        assert!(cors.iter().all(|c| (c - 1.0).abs() < 1e-9));
    }

    #[test]
    fn default_config_scaled() {
        let c = NpsConfig::default();
        assert!(c.n_seqs >= 8);
        assert!(c.seq_len >= 32);
    }
}
