//! Legacy construction structs, kept as thin compatibility views over
//! [`ServerConfig`](super::ServerConfig).
//!
//! [`ServerOptions`] and [`BatcherOptions`] predate the unified
//! [`ServerConfig`](super::ServerConfig) builder (they were the
//! construction APIs for `Server::start_with` and
//! `Batcher::with_options`). They now live here, in one place, and are
//! re-exported at their historical paths (`crate::server::ServerOptions`,
//! `crate::server::batcher::BatcherOptions`) so downstream embedders
//! keep compiling. All in-tree call sites have moved to `ServerConfig`;
//! new code should too. Conversions are lossless in both directions for
//! the fields the legacy structs carry — knobs they never had
//! (chunk budget, watermarks, backend) take `ServerConfig` defaults.

use std::path::PathBuf;

use super::ServerConfig;
use crate::engine::prefix_cache::DEFAULT_CACHE_BYTES;
use crate::server::{DEFAULT_CONN_BUFFER_BYTES, DEFAULT_MAX_FRAME_BYTES};

/// Construction knobs for [`crate::server::Server::start_with`].
///
/// **Deprecation note:** new code should build a
/// [`ServerConfig`] (the unified builder covering these knobs plus
/// chunk budget, backpressure watermarks, and the execution backend)
/// and call [`crate::server::Server::start_with_config`];
/// `ServerOptions` remains as a thin compatibility view and converts
/// losslessly via `From` in both directions.
#[derive(Debug, Clone)]
pub struct ServerOptions {
    /// Decode slot count per shard (must fit a compiled `decode_b{W}`).
    pub batch_width: usize,
    /// Total shared-prefix cache byte budget, split evenly across
    /// shards; 0 disables the cache.
    pub cache_bytes: usize,
    /// Cluster same-prefix requests at each shard's scheduler and defer
    /// same-prefix admissions behind an in-flight publisher.
    pub group_prefixes: bool,
    /// Serving shard count (engine + reactor threads); 1 = unsharded.
    pub shards: usize,
    /// Largest accepted wire frame; bounds the per-connection read
    /// buffer. Oversized frames are a protocol error that closes the
    /// connection.
    pub max_frame_bytes: usize,
    /// Outbound buffer cap per connection; a consumer that falls this
    /// far behind is disconnected.
    pub conn_buffer_bytes: usize,
    /// Directory for persistent prefix-cache snapshots (`--cache-dir`):
    /// each shard warm-starts from `prefix-shard-<i>.gpxs` here and
    /// [`crate::server::Server::stop`] rewrites the files after drain.
    /// None (default) disables persistence.
    pub cache_dir: Option<PathBuf>,
}

impl ServerOptions {
    /// Defaults for everything except the batch width.
    pub fn new(batch_width: usize) -> ServerOptions {
        ServerOptions {
            batch_width,
            cache_bytes: DEFAULT_CACHE_BYTES,
            group_prefixes: true,
            shards: 1,
            max_frame_bytes: DEFAULT_MAX_FRAME_BYTES,
            conn_buffer_bytes: DEFAULT_CONN_BUFFER_BYTES,
            cache_dir: None,
        }
    }

    /// Builder-style shard count override.
    pub fn with_shards(mut self, shards: usize) -> ServerOptions {
        self.shards = shards;
        self
    }

    /// Builder-style frame-size cap override.
    pub fn with_max_frame_bytes(mut self, n: usize) -> ServerOptions {
        self.max_frame_bytes = n;
        self
    }

    /// Builder-style persistent-cache directory override.
    pub fn with_cache_dir(
        mut self,
        dir: Option<PathBuf>,
    ) -> ServerOptions {
        self.cache_dir = dir;
        self
    }
}

impl From<ServerOptions> for ServerConfig {
    /// Lossless upgrade from the legacy options struct: every
    /// `ServerOptions` field maps to its `ServerConfig` namesake and
    /// the knobs it never had take their defaults.
    fn from(o: ServerOptions) -> ServerConfig {
        ServerConfig {
            shards: o.shards,
            cache_bytes: o.cache_bytes,
            cache_dir: o.cache_dir,
            group_prefixes: o.group_prefixes,
            max_frame_bytes: o.max_frame_bytes,
            conn_buffer_bytes: o.conn_buffer_bytes,
            ..ServerConfig::new(o.batch_width)
        }
    }
}

impl From<&ServerConfig> for ServerOptions {
    /// Downgrade for embedders still holding the legacy type: the
    /// shared fields copy over; `ServerConfig`-only knobs (bind, chunk
    /// budget, watermarks, backend) are dropped.
    fn from(c: &ServerConfig) -> ServerOptions {
        ServerOptions {
            batch_width: c.batch_width,
            cache_bytes: c.cache_bytes,
            group_prefixes: c.group_prefixes,
            shards: c.shards,
            max_frame_bytes: c.max_frame_bytes,
            conn_buffer_bytes: c.conn_buffer_bytes,
            cache_dir: c.cache_dir.clone(),
        }
    }
}

/// Construction knobs for
/// [`crate::server::batcher::Batcher::with_options`].
///
/// **Deprecation note:** when standing up a whole server, build a
/// [`ServerConfig`] instead —
/// [`crate::server::Server::start_with_config`] derives each shard's
/// `BatcherOptions` from it via [`BatcherOptions::for_shard`]. This
/// struct remains the direct-embedding API for code that drives a
/// [`crate::server::batcher::Batcher`] without the server.
#[derive(Debug, Clone)]
pub struct BatcherOptions {
    /// Decode slot count (must fit a compiled `decode_b{W}`).
    pub batch_width: usize,
    /// Shared-prefix cache byte budget; 0 disables the cache.
    pub cache_bytes: usize,
    /// Prefill chunks advanced per decode step (clamped to ≥ 1).
    pub chunk_budget: usize,
    /// Defer same-prefix admissions behind an in-flight publisher.
    pub group_prefixes: bool,
    /// Persistent snapshot file for this shard's prefix cache
    /// (`--cache-dir`): warm-loaded at construction, written by
    /// [`crate::server::batcher::Batcher::snapshot_hot`] after the run
    /// loop drains. None (the default) disables persistence.
    pub snapshot_path: Option<PathBuf>,
}

impl BatcherOptions {
    /// Defaults for everything except the batch width.
    pub fn new(batch_width: usize) -> BatcherOptions {
        BatcherOptions {
            batch_width,
            cache_bytes: DEFAULT_CACHE_BYTES,
            chunk_budget: 1,
            group_prefixes: true,
            snapshot_path: None,
        }
    }

    /// Disable the shared-prefix cache (and with it, deferral).
    pub fn without_cache(mut self) -> BatcherOptions {
        self.cache_bytes = 0;
        self
    }

    /// Persist the prefix cache to (and warm-start it from) this file.
    pub fn with_snapshot_path(
        mut self,
        path: Option<PathBuf>,
    ) -> BatcherOptions {
        self.snapshot_path = path;
        self
    }

    /// One shard's slice of a [`ServerConfig`]: the cache budget is
    /// split evenly across shards and the snapshot file (when
    /// persistence is on) is the shard-indexed `.gpxs` under
    /// `cache_dir`. This is the single place the server-level config
    /// is lowered to per-shard batcher knobs.
    pub fn for_shard(cfg: &ServerConfig, shard_id: usize) -> BatcherOptions {
        BatcherOptions {
            batch_width: cfg.batch_width,
            cache_bytes: cfg.cache_bytes / cfg.shards.max(1),
            chunk_budget: cfg.chunk_budget,
            group_prefixes: cfg.group_prefixes,
            snapshot_path: cfg.cache_dir.as_deref().map(|dir| {
                crate::engine::prefix_store::snapshot_path(dir, shard_id)
            }),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn options_default_to_one_shard_with_bounded_buffers() {
        let o = ServerOptions::new(4);
        assert_eq!(o.shards, 1, "default must preserve the unsharded server");
        assert_eq!(o.max_frame_bytes, DEFAULT_MAX_FRAME_BYTES);
        assert_eq!(o.conn_buffer_bytes, DEFAULT_CONN_BUFFER_BYTES);
        let o = o.with_shards(4).with_max_frame_bytes(4096);
        assert_eq!(o.shards, 4);
        assert_eq!(o.max_frame_bytes, 4096);
    }

    #[test]
    fn server_options_round_trip_through_server_config() {
        let opts = ServerOptions::new(4)
            .with_shards(3)
            .with_max_frame_bytes(4096)
            .with_cache_dir(Some(PathBuf::from("/tmp/w")));
        let cfg = ServerConfig::from(opts.clone());
        assert_eq!(cfg.batch_width, 4);
        assert_eq!(cfg.shards, 3);
        assert_eq!(cfg.max_frame_bytes, 4096);
        assert_eq!(cfg.cache_dir, Some(PathBuf::from("/tmp/w")));
        assert_eq!(cfg.backend, "auto", "new knobs take defaults");
        let back = ServerOptions::from(&cfg);
        assert_eq!(back.batch_width, opts.batch_width);
        assert_eq!(back.cache_bytes, opts.cache_bytes);
        assert_eq!(back.group_prefixes, opts.group_prefixes);
        assert_eq!(back.shards, opts.shards);
        assert_eq!(back.max_frame_bytes, opts.max_frame_bytes);
        assert_eq!(back.conn_buffer_bytes, opts.conn_buffer_bytes);
        assert_eq!(back.cache_dir, opts.cache_dir);
    }

    #[test]
    fn batcher_options_for_shard_splits_cache_and_indexes_snapshot() {
        let cfg = ServerConfig::new(2)
            .with_shards(4)
            .with_cache_bytes(1 << 20)
            .with_chunk_budget(3)
            .with_cache_dir(Some(PathBuf::from("/tmp/warm")));
        let b = BatcherOptions::for_shard(&cfg, 2);
        assert_eq!(b.batch_width, 2);
        assert_eq!(b.cache_bytes, (1 << 20) / 4, "budget splits evenly");
        assert_eq!(b.chunk_budget, 3);
        assert!(b.group_prefixes);
        let snap = b.snapshot_path.expect("persistence is on");
        assert!(
            snap.to_string_lossy().contains("2"),
            "snapshot file is shard-indexed: {}",
            snap.display()
        );
        assert!(snap.starts_with("/tmp/warm"));

        let cfg = ServerConfig::new(2).with_cache_dir(None);
        let b = BatcherOptions::for_shard(&cfg, 0);
        assert_eq!(b.snapshot_path, None, "no dir, no persistence");
    }
}
