//! TOML-subset parser: `[section]` headers, `key = value` with strings,
//! integers, floats, booleans, and flat arrays of those. Comments with
//! `#`. Keys are exposed flat as "section.key". Enough for run configs;
//! not a general TOML implementation (no nested tables inline, no dates).

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

#[derive(Debug, Clone, PartialEq)]
pub enum TomlValue {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Array(Vec<TomlValue>),
}

impl TomlValue {
    pub fn as_str(&self) -> Result<&str> {
        match self {
            TomlValue::Str(s) => Ok(s),
            other => bail!("expected string, got {other:?}"),
        }
    }

    pub fn as_int(&self) -> Result<i64> {
        match self {
            TomlValue::Int(i) => Ok(*i),
            other => bail!("expected integer, got {other:?}"),
        }
    }

    pub fn as_float(&self) -> Result<f64> {
        match self {
            TomlValue::Float(f) => Ok(*f),
            TomlValue::Int(i) => Ok(*i as f64),
            other => bail!("expected float, got {other:?}"),
        }
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            TomlValue::Bool(b) => Ok(*b),
            other => bail!("expected bool, got {other:?}"),
        }
    }

    pub fn as_float_list(&self) -> Result<Vec<f64>> {
        match self {
            TomlValue::Array(a) => a.iter().map(|v| v.as_float()).collect(),
            other => bail!("expected array, got {other:?}"),
        }
    }
}

#[derive(Debug, Clone, Default)]
pub struct TomlDoc {
    map: BTreeMap<String, TomlValue>,
}

impl TomlDoc {
    pub fn get(&self, key: &str) -> Option<&TomlValue> {
        self.map.get(key)
    }

    pub fn keys(&self) -> impl Iterator<Item = &String> {
        self.map.keys()
    }
}

pub fn parse_toml(text: &str) -> Result<TomlDoc> {
    let mut doc = TomlDoc::default();
    let mut section = String::new();
    for (lineno, raw) in text.lines().enumerate() {
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix('[') {
            let name = rest
                .strip_suffix(']')
                .ok_or_else(|| anyhow!("line {}: bad section", lineno + 1))?;
            section = name.trim().to_string();
            continue;
        }
        let (key, val) = line.split_once('=').ok_or_else(|| {
            anyhow!("line {}: expected 'key = value'", lineno + 1)
        })?;
        let key = key.trim();
        if key.is_empty() {
            bail!("line {}: empty key", lineno + 1);
        }
        let full = if section.is_empty() {
            key.to_string()
        } else {
            format!("{section}.{key}")
        };
        let value = parse_value(val.trim())
            .map_err(|e| anyhow!("line {}: {e}", lineno + 1))?;
        doc.map.insert(full, value);
    }
    Ok(doc)
}

fn strip_comment(line: &str) -> &str {
    // respect '#' inside quoted strings
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<TomlValue> {
    if s.is_empty() {
        bail!("empty value");
    }
    if let Some(inner) = s.strip_prefix('"') {
        let inner = inner
            .strip_suffix('"')
            .ok_or_else(|| anyhow!("unterminated string"))?;
        return Ok(TomlValue::Str(unescape(inner)?));
    }
    if s == "true" {
        return Ok(TomlValue::Bool(true));
    }
    if s == "false" {
        return Ok(TomlValue::Bool(false));
    }
    if let Some(inner) = s.strip_prefix('[') {
        let inner = inner
            .strip_suffix(']')
            .ok_or_else(|| anyhow!("unterminated array"))?;
        let inner = inner.trim();
        if inner.is_empty() {
            return Ok(TomlValue::Array(Vec::new()));
        }
        let items: Result<Vec<_>> = split_top_level(inner)
            .iter()
            .map(|p| parse_value(p.trim()))
            .collect();
        return Ok(TomlValue::Array(items?));
    }
    if !s.contains('.') && !s.contains('e') && !s.contains('E') {
        if let Ok(i) = s.parse::<i64>() {
            return Ok(TomlValue::Int(i));
        }
    }
    if let Ok(f) = s.parse::<f64>() {
        return Ok(TomlValue::Float(f));
    }
    bail!("cannot parse value '{s}'")
}

fn split_top_level(s: &str) -> Vec<String> {
    let mut parts = Vec::new();
    let mut depth = 0;
    let mut in_str = false;
    let mut cur = String::new();
    for c in s.chars() {
        match c {
            '"' => {
                in_str = !in_str;
                cur.push(c);
            }
            '[' if !in_str => {
                depth += 1;
                cur.push(c);
            }
            ']' if !in_str => {
                depth -= 1;
                cur.push(c);
            }
            ',' if !in_str && depth == 0 => {
                parts.push(std::mem::take(&mut cur));
            }
            _ => cur.push(c),
        }
    }
    if !cur.trim().is_empty() {
        parts.push(cur);
    }
    parts
}

fn unescape(s: &str) -> Result<String> {
    let mut out = String::new();
    let mut chars = s.chars();
    while let Some(c) = chars.next() {
        if c == '\\' {
            match chars.next() {
                Some('n') => out.push('\n'),
                Some('t') => out.push('\t'),
                Some('"') => out.push('"'),
                Some('\\') => out.push('\\'),
                other => bail!("bad escape \\{other:?}"),
            }
        } else {
            out.push(c);
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars_and_sections() {
        let doc = parse_toml(
            "a = 1\nb = 2.5\nc = \"hi\"\nd = true\n[sec]\ne = [1, 2, 3]\n",
        )
        .unwrap();
        assert_eq!(doc.get("a").unwrap().as_int().unwrap(), 1);
        assert_eq!(doc.get("b").unwrap().as_float().unwrap(), 2.5);
        assert_eq!(doc.get("c").unwrap().as_str().unwrap(), "hi");
        assert!(doc.get("d").unwrap().as_bool().unwrap());
        assert_eq!(
            doc.get("sec.e").unwrap().as_float_list().unwrap(),
            vec![1.0, 2.0, 3.0]
        );
    }

    #[test]
    fn comments_stripped() {
        let doc =
            parse_toml("# header\na = 5 # trailing\ns = \"x # y\"\n").unwrap();
        assert_eq!(doc.get("a").unwrap().as_int().unwrap(), 5);
        assert_eq!(doc.get("s").unwrap().as_str().unwrap(), "x # y");
    }

    #[test]
    fn float_arrays() {
        let doc = parse_toml("grid = [0.9, 0.5, 0.1]\n").unwrap();
        assert_eq!(
            doc.get("grid").unwrap().as_float_list().unwrap(),
            vec![0.9, 0.5, 0.1]
        );
    }

    #[test]
    fn int_coerces_to_float() {
        let doc = parse_toml("x = 3\n").unwrap();
        assert_eq!(doc.get("x").unwrap().as_float().unwrap(), 3.0);
    }

    #[test]
    fn errors_have_line_numbers() {
        let err = parse_toml("good = 1\nbad line\n").unwrap_err();
        assert!(err.to_string().contains("line 2"));
    }

    #[test]
    fn escapes_in_strings() {
        let doc = parse_toml("s = \"a\\nb\"\n").unwrap();
        assert_eq!(doc.get("s").unwrap().as_str().unwrap(), "a\nb");
    }
}
