//! Run configuration: typed experiment settings + a TOML-subset parser.
//!
//! Precedence (lowest to highest): built-in defaults → config file
//! (`--config path.toml`) → CLI overrides. The defaults are sized so the
//! full experiment suite finishes in minutes on one CPU core; the paper's
//! full-scale settings are noted field-by-field.

mod toml;

pub use toml::{parse_toml, TomlValue};

use anyhow::Result;
use std::path::PathBuf;

use crate::util::cli::Args;

/// Everything a harness run needs.
#[derive(Debug, Clone)]
pub struct RunConfig {
    /// Artifact bundle directory (manifest.json, *.hlo.txt, params.bin).
    pub artifacts_dir: PathBuf,
    /// Where experiment reports (json + md) are written.
    pub results_dir: PathBuf,
    /// LG benchmark samples (paper: 3,602 Alpaca samples).
    pub lg_samples: usize,
    /// Samples for the density sweep, Tab. 3 (heavier: 9 densities).
    pub sweep_samples: usize,
    /// Classification items per family (paper: full benchmark sets).
    pub cls_samples: usize,
    /// Short-generation items per family.
    pub sg_samples: usize,
    /// Held-out sequences for the oracle-overlap analysis (paper: 100).
    pub oracle_samples: usize,
    /// Default sparsity density (paper headline: 0.5).
    pub density: f64,
    /// GLASS mixing weight λ (paper default 0.5 = equal reliability).
    pub lambda: f64,
    /// λ sweep grid for Fig. 4 (paper: 0..1 step 0.05).
    pub lambda_grid: Vec<f64>,
    /// Density grid for Tab. 3 (paper: 90%..10% step 10%).
    pub density_grid: Vec<f64>,
    /// Batch size used by batched harness runs (must match a compiled
    /// executable: b1 or b4).
    pub batch: usize,
    /// Top-100 KLD truncation (App. B.2.2).
    pub kld_top: usize,
    /// Base seed for all harness randomness.
    pub seed: u64,
    /// Server bind address for `glass serve`.
    pub bind: String,
    /// Shared-prefix cache byte budget for `glass serve` (0 = off),
    /// split evenly across serving shards.
    pub cache_bytes: usize,
    /// Serving shard count for `glass serve` (per-shard engine thread,
    /// reactor thread, scheduler queue, and prefix cache; 1 = the
    /// unsharded server).
    pub shards: usize,
    /// Wire protocol `glass client` speaks: "v2" (framed streaming
    /// sessions, default) or "v1" (legacy one-shot lines). The server
    /// auto-detects per connection and always serves both.
    pub protocol: String,
    /// Largest accepted wire frame (`glass serve`); bounds each
    /// connection's read buffer.
    pub max_frame_bytes: usize,
    /// Outbound buffer cap per connection (`glass serve`); a consumer
    /// that falls this far behind is disconnected.
    pub conn_buffer_bytes: usize,
    /// Directory for persistent prefix-cache snapshots (`glass serve`).
    /// When set, `Server::stop` writes each shard's hot entries there
    /// and the next startup warm-starts from them; unset (default)
    /// disables persistence entirely.
    pub cache_dir: Option<PathBuf>,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            artifacts_dir: PathBuf::from("artifacts"),
            results_dir: PathBuf::from("results"),
            lg_samples: 96,
            sweep_samples: 32,
            cls_samples: 24,
            sg_samples: 16,
            oracle_samples: 48,
            density: 0.5,
            lambda: 0.5,
            lambda_grid: (0..=10).map(|i| i as f64 / 10.0).collect(),
            density_grid: vec![0.9, 0.8, 0.7, 0.6, 0.5, 0.4, 0.3, 0.2, 0.1],
            batch: 4,
            kld_top: 100,
            seed: 0,
            bind: "127.0.0.1:7433".to_string(),
            cache_bytes:
                crate::engine::prefix_cache::DEFAULT_CACHE_BYTES,
            shards: 1,
            protocol: "v2".to_string(),
            max_frame_bytes: crate::server::DEFAULT_MAX_FRAME_BYTES,
            conn_buffer_bytes: crate::server::DEFAULT_CONN_BUFFER_BYTES,
            cache_dir: None,
        }
    }
}

impl RunConfig {
    /// Load from optional TOML file then apply CLI overrides.
    pub fn load(args: &Args) -> Result<RunConfig> {
        let mut cfg = RunConfig::default();
        if let Some(path) = args.get("config") {
            cfg.apply_toml(&std::fs::read_to_string(path)?)?;
        }
        cfg.apply_args(args)?;
        Ok(cfg)
    }

    pub fn apply_toml(&mut self, text: &str) -> Result<()> {
        let doc = parse_toml(text)?;
        let get = |k: &str| doc.get(&format!("run.{k}")).or_else(|| doc.get(k));
        if let Some(v) = get("artifacts_dir") {
            self.artifacts_dir = PathBuf::from(v.as_str()?);
        }
        if let Some(v) = get("results_dir") {
            self.results_dir = PathBuf::from(v.as_str()?);
        }
        if let Some(v) = get("lg_samples") {
            self.lg_samples = v.as_int()? as usize;
        }
        if let Some(v) = get("sweep_samples") {
            self.sweep_samples = v.as_int()? as usize;
        }
        if let Some(v) = get("cls_samples") {
            self.cls_samples = v.as_int()? as usize;
        }
        if let Some(v) = get("sg_samples") {
            self.sg_samples = v.as_int()? as usize;
        }
        if let Some(v) = get("oracle_samples") {
            self.oracle_samples = v.as_int()? as usize;
        }
        if let Some(v) = get("density") {
            self.density = v.as_float()?;
        }
        if let Some(v) = get("lambda") {
            self.lambda = v.as_float()?;
        }
        if let Some(v) = get("lambda_grid") {
            self.lambda_grid = v.as_float_list()?;
        }
        if let Some(v) = get("density_grid") {
            self.density_grid = v.as_float_list()?;
        }
        if let Some(v) = get("batch") {
            self.batch = v.as_int()? as usize;
        }
        if let Some(v) = get("kld_top") {
            self.kld_top = v.as_int()? as usize;
        }
        if let Some(v) = get("seed") {
            self.seed = v.as_int()? as u64;
        }
        if let Some(v) = get("bind") {
            self.bind = v.as_str()?.to_string();
        }
        if let Some(v) = get("cache_bytes") {
            self.cache_bytes = v.as_int()? as usize;
        }
        if let Some(v) = get("shards") {
            self.shards = v.as_int()? as usize;
        }
        if let Some(v) = get("protocol") {
            self.protocol = v.as_str()?.to_string();
        }
        if let Some(v) = get("max_frame_bytes") {
            self.max_frame_bytes = v.as_int()? as usize;
        }
        if let Some(v) = get("conn_buffer_bytes") {
            self.conn_buffer_bytes = v.as_int()? as usize;
        }
        if let Some(v) = get("cache_dir") {
            self.cache_dir = Some(PathBuf::from(v.as_str()?));
        }
        Ok(())
    }

    pub fn apply_args(&mut self, args: &Args) -> Result<()> {
        if let Some(v) = args.get("artifacts") {
            self.artifacts_dir = PathBuf::from(v);
        }
        if let Some(v) = args.get("results") {
            self.results_dir = PathBuf::from(v);
        }
        self.lg_samples = args.get_usize("lg-samples", self.lg_samples)?;
        self.sweep_samples =
            args.get_usize("sweep-samples", self.sweep_samples)?;
        self.cls_samples = args.get_usize("cls-samples", self.cls_samples)?;
        self.sg_samples = args.get_usize("sg-samples", self.sg_samples)?;
        self.oracle_samples =
            args.get_usize("oracle-samples", self.oracle_samples)?;
        self.density = args.get_f64("density", self.density)?;
        self.lambda = args.get_f64("lambda", self.lambda)?;
        self.lambda_grid =
            args.get_f64_list("lambda-grid", &self.lambda_grid)?;
        self.density_grid =
            args.get_f64_list("density-grid", &self.density_grid)?;
        self.batch = args.get_usize("batch", self.batch)?;
        self.kld_top = args.get_usize("kld-top", self.kld_top)?;
        self.seed = args.get_usize("seed", self.seed as usize)? as u64;
        if let Some(v) = args.get("bind") {
            self.bind = v.to_string();
        }
        self.cache_bytes =
            args.get_usize("cache-bytes", self.cache_bytes)?;
        self.shards = args.get_usize("shards", self.shards)?;
        if let Some(v) = args.get("protocol") {
            self.protocol = v.to_string();
        }
        self.max_frame_bytes =
            args.get_usize("max-frame-bytes", self.max_frame_bytes)?;
        self.conn_buffer_bytes = args
            .get_usize("conn-buffer-bytes", self.conn_buffer_bytes)?;
        if let Some(v) = args.get("cache-dir") {
            self.cache_dir = Some(PathBuf::from(v));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_sane() {
        let c = RunConfig::default();
        assert_eq!(c.density, 0.5);
        assert_eq!(c.lambda, 0.5);
        assert_eq!(c.density_grid.len(), 9);
        assert!(c.batch == 4);
    }

    #[test]
    fn toml_overrides() {
        let mut c = RunConfig::default();
        c.apply_toml(
            "lg_samples = 10\ndensity = 0.25\nlambda_grid = [0.0, 1.0]\n\
             bind = \"0.0.0.0:9\"\n",
        )
        .unwrap();
        assert_eq!(c.lg_samples, 10);
        assert_eq!(c.density, 0.25);
        assert_eq!(c.lambda_grid, vec![0.0, 1.0]);
        assert_eq!(c.bind, "0.0.0.0:9");
    }

    #[test]
    fn toml_section_form() {
        let mut c = RunConfig::default();
        c.apply_toml("[run]\nseed = 7\nbatch = 1\n").unwrap();
        assert_eq!(c.seed, 7);
        assert_eq!(c.batch, 1);
    }

    #[test]
    fn shards_knob_defaults_and_overrides() {
        let c = RunConfig::default();
        assert_eq!(c.shards, 1, "default must be the unsharded server");
        let mut c = RunConfig::default();
        c.apply_toml("shards = 4\n").unwrap();
        assert_eq!(c.shards, 4);
        let args = Args::parse(
            &["x", "--shards", "2"]
                .iter()
                .map(|s| s.to_string())
                .collect::<Vec<_>>(),
            &[],
        )
        .unwrap();
        c.apply_args(&args).unwrap();
        assert_eq!(c.shards, 2, "CLI overrides the config file");
    }

    #[test]
    fn protocol_and_buffer_knobs_default_and_override() {
        let c = RunConfig::default();
        assert_eq!(c.protocol, "v2", "client defaults to the new wire");
        assert_eq!(
            c.max_frame_bytes,
            crate::server::DEFAULT_MAX_FRAME_BYTES
        );
        assert_eq!(c.cache_dir, None, "persistence is opt-in");
        let mut c = RunConfig::default();
        c.apply_toml(
            "protocol = \"v1\"\nmax_frame_bytes = 4096\n\
             conn_buffer_bytes = 65536\n\
             cache_dir = \"/var/glass/cache\"\n",
        )
        .unwrap();
        assert_eq!(c.protocol, "v1");
        assert_eq!(c.max_frame_bytes, 4096);
        assert_eq!(c.conn_buffer_bytes, 65536);
        assert_eq!(
            c.cache_dir,
            Some(PathBuf::from("/var/glass/cache"))
        );
        let args = Args::parse(
            &[
                "x",
                "--protocol",
                "v2",
                "--max-frame-bytes",
                "8192",
                "--cache-dir",
                "/tmp/warm",
            ]
            .iter()
            .map(|s| s.to_string())
            .collect::<Vec<_>>(),
            &[],
        )
        .unwrap();
        c.apply_args(&args).unwrap();
        assert_eq!(c.protocol, "v2", "CLI overrides the config file");
        assert_eq!(c.max_frame_bytes, 8192);
        assert_eq!(c.cache_dir, Some(PathBuf::from("/tmp/warm")));
    }

    #[test]
    fn cli_overrides() {
        let args = Args::parse(
            &["x", "--density", "0.3", "--lambda-grid", "0.1,0.9"]
                .iter()
                .map(|s| s.to_string())
                .collect::<Vec<_>>(),
            &[],
        )
        .unwrap();
        let mut c = RunConfig::default();
        c.apply_args(&args).unwrap();
        assert_eq!(c.density, 0.3);
        assert_eq!(c.lambda_grid, vec![0.1, 0.9]);
    }
}
