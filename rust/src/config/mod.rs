//! Run configuration: typed experiment settings + a TOML-subset parser.
//!
//! Precedence (lowest to highest): built-in defaults → config file
//! (`--config path.toml`) → CLI overrides. The defaults are sized so the
//! full experiment suite finishes in minutes on one CPU core; the paper's
//! full-scale settings are noted field-by-field.
//!
//! Serving knobs have a second, embeddable face: [`ServerConfig`] is
//! the unified builder that `glass serve` (and embedders calling
//! [`crate::server::Server::start_with_config`]) construct — either
//! field-by-field with `with_*` methods or projected from a loaded
//! [`RunConfig`] via [`ServerConfig::from_run`].

pub mod compat;
mod toml;

pub use toml::{parse_toml, TomlValue};

use anyhow::Result;
use std::path::PathBuf;

use crate::util::cli::Args;

/// Everything a harness run needs.
#[derive(Debug, Clone)]
pub struct RunConfig {
    /// Artifact bundle directory (manifest.json, *.hlo.txt, params.bin).
    pub artifacts_dir: PathBuf,
    /// Where experiment reports (json + md) are written.
    pub results_dir: PathBuf,
    /// LG benchmark samples (paper: 3,602 Alpaca samples).
    pub lg_samples: usize,
    /// Samples for the density sweep, Tab. 3 (heavier: 9 densities).
    pub sweep_samples: usize,
    /// Classification items per family (paper: full benchmark sets).
    pub cls_samples: usize,
    /// Short-generation items per family.
    pub sg_samples: usize,
    /// Held-out sequences for the oracle-overlap analysis (paper: 100).
    pub oracle_samples: usize,
    /// Default sparsity density (paper headline: 0.5).
    pub density: f64,
    /// GLASS mixing weight λ (paper default 0.5 = equal reliability).
    pub lambda: f64,
    /// λ sweep grid for Fig. 4 (paper: 0..1 step 0.05).
    pub lambda_grid: Vec<f64>,
    /// Density grid for Tab. 3 (paper: 90%..10% step 10%).
    pub density_grid: Vec<f64>,
    /// Batch size used by batched harness runs (must match a compiled
    /// executable: b1 or b4).
    pub batch: usize,
    /// Top-100 KLD truncation (App. B.2.2).
    pub kld_top: usize,
    /// Base seed for all harness randomness.
    pub seed: u64,
    /// Server bind address for `glass serve`.
    pub bind: String,
    /// Shared-prefix cache byte budget for `glass serve` (0 = off),
    /// split evenly across serving shards.
    pub cache_bytes: usize,
    /// Serving shard count for `glass serve` (per-shard engine thread,
    /// reactor thread, scheduler queue, and prefix cache; 1 = the
    /// unsharded server).
    pub shards: usize,
    /// Wire protocol `glass client` speaks: "v2" (framed streaming
    /// sessions, default) or "v1" (legacy one-shot lines). The server
    /// auto-detects per connection and always serves both.
    pub protocol: String,
    /// Largest accepted wire frame (`glass serve`); bounds each
    /// connection's read buffer.
    pub max_frame_bytes: usize,
    /// Outbound buffer cap per connection (`glass serve`); also the
    /// default backpressure high-water mark — a consumer that falls
    /// this far behind has its sessions parked (not disconnected)
    /// until the buffer drains below the low-water mark.
    pub conn_buffer_bytes: usize,
    /// Backpressure high-water mark in bytes (`glass serve`): a
    /// connection whose outbound backlog crosses this parks its
    /// decode slots. 0 (default) derives it from `conn_buffer_bytes`.
    pub high_water_bytes: usize,
    /// Backpressure low-water mark in bytes (`glass serve`): a parked
    /// connection resumes once its backlog drains below this. 0
    /// (default) derives a quarter of the high-water mark.
    pub low_water_bytes: usize,
    /// Directory for persistent prefix-cache snapshots (`glass serve`).
    /// When set, `Server::stop` writes each shard's hot entries there
    /// and the next startup warm-starts from them; unset (default)
    /// disables persistence entirely.
    pub cache_dir: Option<PathBuf>,
    /// Execution backend by registry name: `"auto"` (default — PJRT
    /// when compiled in, otherwise the simulator), `"sim"`,
    /// `"cpu-q8"` (int8 weight-quantized CPU GEMV with native masked
    /// FFN), or `"pjrt"`. Unknown names are rejected at parse time;
    /// see [`crate::runtime::BACKEND_NAMES`].
    pub backend: String,
    /// Overload governor for `glass serve`: SLO-tiered degradation of
    /// GLASS knobs under load plus hot-prefix work-stealing (see the
    /// server's "Load governance" docs). Default off — disabled, the
    /// serving stack behaves knob-for-knob like the ungoverned server.
    pub governor: bool,
    /// Per-tier effective-density floors `[interactive, standard,
    /// batch]` the governor never degrades past.
    pub governor_floors: [f64; 3],
    /// Home-shard pressure (outstanding work / batch width) at or past
    /// which an idle sibling shard may steal an admission.
    pub steal_threshold: f64,
}

impl Default for RunConfig {
    fn default() -> Self {
        // one source of truth for the governor's defaults
        let gov = crate::server::governor::GovernorConfig::default();
        RunConfig {
            artifacts_dir: PathBuf::from("artifacts"),
            results_dir: PathBuf::from("results"),
            lg_samples: 96,
            sweep_samples: 32,
            cls_samples: 24,
            sg_samples: 16,
            oracle_samples: 48,
            density: 0.5,
            lambda: 0.5,
            lambda_grid: (0..=10).map(|i| i as f64 / 10.0).collect(),
            density_grid: vec![0.9, 0.8, 0.7, 0.6, 0.5, 0.4, 0.3, 0.2, 0.1],
            batch: 4,
            kld_top: 100,
            seed: 0,
            bind: "127.0.0.1:7433".to_string(),
            cache_bytes:
                crate::engine::prefix_cache::DEFAULT_CACHE_BYTES,
            shards: 1,
            protocol: "v2".to_string(),
            max_frame_bytes: crate::server::DEFAULT_MAX_FRAME_BYTES,
            conn_buffer_bytes: crate::server::DEFAULT_CONN_BUFFER_BYTES,
            high_water_bytes: 0,
            low_water_bytes: 0,
            cache_dir: None,
            backend: "auto".to_string(),
            governor: gov.enabled,
            governor_floors: gov.floors,
            steal_threshold: gov.steal_threshold,
        }
    }
}

impl RunConfig {
    /// Load from optional TOML file then apply CLI overrides.
    pub fn load(args: &Args) -> Result<RunConfig> {
        let mut cfg = RunConfig::default();
        if let Some(path) = args.get("config") {
            cfg.apply_toml(&std::fs::read_to_string(path)?)?;
        }
        cfg.apply_args(args)?;
        Ok(cfg)
    }

    pub fn apply_toml(&mut self, text: &str) -> Result<()> {
        let doc = parse_toml(text)?;
        let get = |k: &str| doc.get(&format!("run.{k}")).or_else(|| doc.get(k));
        if let Some(v) = get("artifacts_dir") {
            self.artifacts_dir = PathBuf::from(v.as_str()?);
        }
        if let Some(v) = get("results_dir") {
            self.results_dir = PathBuf::from(v.as_str()?);
        }
        if let Some(v) = get("lg_samples") {
            self.lg_samples = v.as_int()? as usize;
        }
        if let Some(v) = get("sweep_samples") {
            self.sweep_samples = v.as_int()? as usize;
        }
        if let Some(v) = get("cls_samples") {
            self.cls_samples = v.as_int()? as usize;
        }
        if let Some(v) = get("sg_samples") {
            self.sg_samples = v.as_int()? as usize;
        }
        if let Some(v) = get("oracle_samples") {
            self.oracle_samples = v.as_int()? as usize;
        }
        if let Some(v) = get("density") {
            self.density = v.as_float()?;
        }
        if let Some(v) = get("lambda") {
            self.lambda = v.as_float()?;
        }
        if let Some(v) = get("lambda_grid") {
            self.lambda_grid = v.as_float_list()?;
        }
        if let Some(v) = get("density_grid") {
            self.density_grid = v.as_float_list()?;
        }
        if let Some(v) = get("batch") {
            self.batch = v.as_int()? as usize;
        }
        if let Some(v) = get("kld_top") {
            self.kld_top = v.as_int()? as usize;
        }
        if let Some(v) = get("seed") {
            self.seed = v.as_int()? as u64;
        }
        if let Some(v) = get("bind") {
            self.bind = v.as_str()?.to_string();
        }
        if let Some(v) = get("cache_bytes") {
            self.cache_bytes = v.as_int()? as usize;
        }
        if let Some(v) = get("shards") {
            self.shards = v.as_int()? as usize;
        }
        if let Some(v) = get("protocol") {
            self.protocol = v.as_str()?.to_string();
        }
        if let Some(v) = get("max_frame_bytes") {
            self.max_frame_bytes = v.as_int()? as usize;
        }
        if let Some(v) = get("conn_buffer_bytes") {
            self.conn_buffer_bytes = v.as_int()? as usize;
        }
        if let Some(v) = get("high_water_bytes") {
            self.high_water_bytes = v.as_int()? as usize;
        }
        if let Some(v) = get("low_water_bytes") {
            self.low_water_bytes = v.as_int()? as usize;
        }
        if let Some(v) = get("cache_dir") {
            self.cache_dir = Some(PathBuf::from(v.as_str()?));
        }
        if let Some(v) = get("backend") {
            self.backend = v.as_str()?.to_string();
            crate::runtime::validate_backend_name(&self.backend)?;
        }
        if let Some(v) = get("governor") {
            self.governor = v.as_bool()?;
        }
        if let Some(v) = get("governor_floor_interactive") {
            self.governor_floors[0] = v.as_float()?;
        }
        if let Some(v) = get("governor_floor_standard") {
            self.governor_floors[1] = v.as_float()?;
        }
        if let Some(v) = get("governor_floor_batch") {
            self.governor_floors[2] = v.as_float()?;
        }
        if let Some(v) = get("steal_threshold") {
            self.steal_threshold = v.as_float()?;
        }
        Ok(())
    }

    pub fn apply_args(&mut self, args: &Args) -> Result<()> {
        if let Some(v) = args.get("artifacts") {
            self.artifacts_dir = PathBuf::from(v);
        }
        if let Some(v) = args.get("results") {
            self.results_dir = PathBuf::from(v);
        }
        self.lg_samples = args.get_usize("lg-samples", self.lg_samples)?;
        self.sweep_samples =
            args.get_usize("sweep-samples", self.sweep_samples)?;
        self.cls_samples = args.get_usize("cls-samples", self.cls_samples)?;
        self.sg_samples = args.get_usize("sg-samples", self.sg_samples)?;
        self.oracle_samples =
            args.get_usize("oracle-samples", self.oracle_samples)?;
        self.density = args.get_f64("density", self.density)?;
        self.lambda = args.get_f64("lambda", self.lambda)?;
        self.lambda_grid =
            args.get_f64_list("lambda-grid", &self.lambda_grid)?;
        self.density_grid =
            args.get_f64_list("density-grid", &self.density_grid)?;
        self.batch = args.get_usize("batch", self.batch)?;
        self.kld_top = args.get_usize("kld-top", self.kld_top)?;
        self.seed = args.get_usize("seed", self.seed as usize)? as u64;
        if let Some(v) = args.get("bind") {
            self.bind = v.to_string();
        }
        self.cache_bytes =
            args.get_usize("cache-bytes", self.cache_bytes)?;
        self.shards = args.get_usize("shards", self.shards)?;
        if let Some(v) = args.get("protocol") {
            self.protocol = v.to_string();
        }
        self.max_frame_bytes =
            args.get_usize("max-frame-bytes", self.max_frame_bytes)?;
        self.conn_buffer_bytes = args
            .get_usize("conn-buffer-bytes", self.conn_buffer_bytes)?;
        self.high_water_bytes = args
            .get_usize("high-water-bytes", self.high_water_bytes)?;
        self.low_water_bytes = args
            .get_usize("low-water-bytes", self.low_water_bytes)?;
        if let Some(v) = args.get("cache-dir") {
            self.cache_dir = Some(PathBuf::from(v));
        }
        if let Some(v) = args.get("backend") {
            self.backend = v.to_string();
            crate::runtime::validate_backend_name(&self.backend)?;
        }
        if let Some(v) = args.get("governor") {
            self.governor = match v {
                "on" => true,
                "off" => false,
                other => anyhow::bail!(
                    "--governor expects on|off, got '{other}'"
                ),
            };
        }
        self.governor_floors[0] = args.get_f64(
            "governor-floor-interactive",
            self.governor_floors[0],
        )?;
        self.governor_floors[1] = args.get_f64(
            "governor-floor-standard",
            self.governor_floors[1],
        )?;
        self.governor_floors[2] = args
            .get_f64("governor-floor-batch", self.governor_floors[2])?;
        self.steal_threshold =
            args.get_f64("steal-threshold", self.steal_threshold)?;
        Ok(())
    }
}

/// The unified server construction config: every knob the serving
/// stack reads, in one builder.
///
/// This replaces the scattered trio of `Server::start_with` arguments,
/// [`compat::ServerOptions`], and [`compat::BatcherOptions`] as the
/// construction API: those two live on only as thin compatibility
/// views in [`compat`] (`ServerConfig` is `From<ServerOptions>`, and
/// `start_with_config` derives the batcher options internally). Build
/// one with [`ServerConfig::new`] plus `with_*` chaining, or project
/// it from a loaded [`RunConfig`] with [`ServerConfig::from_run`],
/// then pass it to [`crate::server::Server::start_with_config`].
#[derive(Debug, Clone)]
pub struct ServerConfig {
    /// Bind address, e.g. `"127.0.0.1:7433"` (`:0` picks a free port).
    pub bind: String,
    /// Serving shard count (per-shard engine thread, reactor thread,
    /// scheduler queue, and prefix cache); 1 = the unsharded server.
    pub shards: usize,
    /// Decode slot count per shard (must fit a compiled `decode_b{W}`).
    pub batch_width: usize,
    /// Total shared-prefix cache byte budget, split evenly across
    /// shards; 0 disables the cache.
    pub cache_bytes: usize,
    /// Directory for persistent prefix-cache snapshots: each shard
    /// warm-starts from `prefix-shard-<i>.gpxs` here and
    /// [`crate::server::Server::stop`] rewrites the files after drain.
    /// None (default) disables persistence.
    pub cache_dir: Option<PathBuf>,
    /// Cluster same-prefix requests at each shard's scheduler and
    /// defer same-prefix admissions behind an in-flight publisher.
    pub group_prefixes: bool,
    /// Prefill chunks advanced per decode step in each shard's batcher
    /// (chunked-prefill fairness knob; min 1).
    pub chunk_budget: usize,
    /// Largest accepted wire frame; bounds the per-connection read
    /// buffer. Oversized frames are a protocol error that closes the
    /// connection.
    pub max_frame_bytes: usize,
    /// Outbound buffer cap per connection and the default backpressure
    /// high-water mark.
    pub conn_buffer_bytes: usize,
    /// Backpressure high-water mark: a connection whose outbound
    /// backlog crosses this has its sessions parked (decode slots ride
    /// along without emitting) until the socket drains. 0 (default) =
    /// use `conn_buffer_bytes`; see [`ServerConfig::resolved_high_water`].
    pub high_water_bytes: usize,
    /// Backpressure low-water mark: a parked connection resumes once
    /// its backlog drains below this. 0 (default) = a quarter of the
    /// high-water mark; see [`ServerConfig::resolved_low_water`].
    pub low_water_bytes: usize,
    /// Execution backend the serving engine is expected to run on, by
    /// registry name (see [`crate::runtime::BACKEND_NAMES`]). `"auto"`
    /// (default) accepts whatever backend the engine was loaded with;
    /// a concrete name makes `start_with_config` fail fast when the
    /// engine's backend doesn't match.
    pub backend: String,
    /// Overload governor (SLO-tiered degradation + hot-prefix
    /// work-stealing; see the server's "Load governance" docs).
    /// Default off.
    pub governor: bool,
    /// Per-tier effective-density floors `[interactive, standard,
    /// batch]` the governor never degrades past.
    pub governor_floors: [f64; 3],
    /// Home-shard pressure at or past which an idle sibling shard may
    /// steal an admission.
    pub steal_threshold: f64,
}

impl ServerConfig {
    /// Defaults for everything except the batch width: localhost bind,
    /// one shard, cache on, persistence off, derived watermarks.
    pub fn new(batch_width: usize) -> ServerConfig {
        let gov = crate::server::governor::GovernorConfig::default();
        ServerConfig {
            bind: "127.0.0.1:7433".to_string(),
            shards: 1,
            batch_width,
            cache_bytes: crate::engine::prefix_cache::DEFAULT_CACHE_BYTES,
            cache_dir: None,
            group_prefixes: true,
            chunk_budget: 1,
            max_frame_bytes: crate::server::DEFAULT_MAX_FRAME_BYTES,
            conn_buffer_bytes: crate::server::DEFAULT_CONN_BUFFER_BYTES,
            high_water_bytes: 0,
            low_water_bytes: 0,
            backend: "auto".to_string(),
            governor: gov.enabled,
            governor_floors: gov.floors,
            steal_threshold: gov.steal_threshold,
        }
    }

    /// Project the serving slice of a loaded [`RunConfig`] (file + CLI
    /// overrides already applied) onto a `ServerConfig`.
    pub fn from_run(run: &RunConfig, batch_width: usize) -> ServerConfig {
        ServerConfig {
            bind: run.bind.clone(),
            shards: run.shards,
            batch_width,
            cache_bytes: run.cache_bytes,
            cache_dir: run.cache_dir.clone(),
            group_prefixes: true,
            chunk_budget: 1,
            max_frame_bytes: run.max_frame_bytes,
            conn_buffer_bytes: run.conn_buffer_bytes,
            high_water_bytes: run.high_water_bytes,
            low_water_bytes: run.low_water_bytes,
            backend: run.backend.clone(),
            governor: run.governor,
            governor_floors: run.governor_floors,
            steal_threshold: run.steal_threshold,
        }
    }

    /// Builder-style bind-address override.
    pub fn with_bind(mut self, bind: &str) -> ServerConfig {
        self.bind = bind.to_string();
        self
    }

    /// Builder-style shard count override.
    pub fn with_shards(mut self, shards: usize) -> ServerConfig {
        self.shards = shards;
        self
    }

    /// Builder-style cache byte-budget override (0 disables).
    pub fn with_cache_bytes(mut self, n: usize) -> ServerConfig {
        self.cache_bytes = n;
        self
    }

    /// Builder-style persistent-cache directory override.
    pub fn with_cache_dir(mut self, dir: Option<PathBuf>) -> ServerConfig {
        self.cache_dir = dir;
        self
    }

    /// Builder-style prefix-grouping toggle.
    pub fn with_group_prefixes(mut self, on: bool) -> ServerConfig {
        self.group_prefixes = on;
        self
    }

    /// Builder-style chunked-prefill budget override.
    pub fn with_chunk_budget(mut self, n: usize) -> ServerConfig {
        self.chunk_budget = n;
        self
    }

    /// Builder-style frame-size cap override.
    pub fn with_max_frame_bytes(mut self, n: usize) -> ServerConfig {
        self.max_frame_bytes = n;
        self
    }

    /// Builder-style outbound buffer cap override.
    pub fn with_conn_buffer_bytes(mut self, n: usize) -> ServerConfig {
        self.conn_buffer_bytes = n;
        self
    }

    /// Builder-style backend-name override (see
    /// [`crate::runtime::BACKEND_NAMES`]). Unknown names are rejected
    /// when the server starts.
    pub fn with_backend(mut self, backend: &str) -> ServerConfig {
        self.backend = backend.to_string();
        self
    }

    /// Builder-style overload-governor toggle (default off).
    pub fn with_governor(mut self, on: bool) -> ServerConfig {
        self.governor = on;
        self
    }

    /// Builder-style per-tier density-floor override
    /// (`[interactive, standard, batch]`).
    pub fn with_governor_floors(
        mut self,
        floors: [f64; 3],
    ) -> ServerConfig {
        self.governor_floors = floors;
        self
    }

    /// Builder-style steal-threshold override (home-shard pressure at
    /// which an idle sibling may steal an admission).
    pub fn with_steal_threshold(mut self, t: f64) -> ServerConfig {
        self.steal_threshold = t;
        self
    }

    /// Builder-style backpressure watermark override (0 = derive).
    pub fn with_watermarks(
        mut self,
        high: usize,
        low: usize,
    ) -> ServerConfig {
        self.high_water_bytes = high;
        self.low_water_bytes = low;
        self
    }

    /// The effective high-water mark: the explicit setting, or the
    /// outbound buffer cap when left at 0.
    pub fn resolved_high_water(&self) -> usize {
        if self.high_water_bytes > 0 {
            self.high_water_bytes
        } else {
            self.conn_buffer_bytes
        }
    }

    /// The effective low-water mark: the explicit setting clamped to
    /// the high-water mark, or a quarter of it when left at 0 (drain
    /// deep enough that resume doesn't immediately re-park, shallow
    /// enough that the socket never idles while slots are parked).
    pub fn resolved_low_water(&self) -> usize {
        let high = self.resolved_high_water();
        if self.low_water_bytes > 0 {
            self.low_water_bytes.min(high)
        } else {
            (high / 4).max(1)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_sane() {
        let c = RunConfig::default();
        assert_eq!(c.density, 0.5);
        assert_eq!(c.lambda, 0.5);
        assert_eq!(c.density_grid.len(), 9);
        assert!(c.batch == 4);
    }

    #[test]
    fn toml_overrides() {
        let mut c = RunConfig::default();
        c.apply_toml(
            "lg_samples = 10\ndensity = 0.25\nlambda_grid = [0.0, 1.0]\n\
             bind = \"0.0.0.0:9\"\n",
        )
        .unwrap();
        assert_eq!(c.lg_samples, 10);
        assert_eq!(c.density, 0.25);
        assert_eq!(c.lambda_grid, vec![0.0, 1.0]);
        assert_eq!(c.bind, "0.0.0.0:9");
    }

    #[test]
    fn toml_section_form() {
        let mut c = RunConfig::default();
        c.apply_toml("[run]\nseed = 7\nbatch = 1\n").unwrap();
        assert_eq!(c.seed, 7);
        assert_eq!(c.batch, 1);
    }

    #[test]
    fn shards_knob_defaults_and_overrides() {
        let c = RunConfig::default();
        assert_eq!(c.shards, 1, "default must be the unsharded server");
        let mut c = RunConfig::default();
        c.apply_toml("shards = 4\n").unwrap();
        assert_eq!(c.shards, 4);
        let args = Args::parse(
            &["x", "--shards", "2"]
                .iter()
                .map(|s| s.to_string())
                .collect::<Vec<_>>(),
            &[],
        )
        .unwrap();
        c.apply_args(&args).unwrap();
        assert_eq!(c.shards, 2, "CLI overrides the config file");
    }

    #[test]
    fn protocol_and_buffer_knobs_default_and_override() {
        let c = RunConfig::default();
        assert_eq!(c.protocol, "v2", "client defaults to the new wire");
        assert_eq!(
            c.max_frame_bytes,
            crate::server::DEFAULT_MAX_FRAME_BYTES
        );
        assert_eq!(c.cache_dir, None, "persistence is opt-in");
        let mut c = RunConfig::default();
        c.apply_toml(
            "protocol = \"v1\"\nmax_frame_bytes = 4096\n\
             conn_buffer_bytes = 65536\n\
             cache_dir = \"/var/glass/cache\"\n",
        )
        .unwrap();
        assert_eq!(c.protocol, "v1");
        assert_eq!(c.max_frame_bytes, 4096);
        assert_eq!(c.conn_buffer_bytes, 65536);
        assert_eq!(
            c.cache_dir,
            Some(PathBuf::from("/var/glass/cache"))
        );
        let args = Args::parse(
            &[
                "x",
                "--protocol",
                "v2",
                "--max-frame-bytes",
                "8192",
                "--cache-dir",
                "/tmp/warm",
            ]
            .iter()
            .map(|s| s.to_string())
            .collect::<Vec<_>>(),
            &[],
        )
        .unwrap();
        c.apply_args(&args).unwrap();
        assert_eq!(c.protocol, "v2", "CLI overrides the config file");
        assert_eq!(c.max_frame_bytes, 8192);
        assert_eq!(c.cache_dir, Some(PathBuf::from("/tmp/warm")));
    }

    #[test]
    fn cli_overrides() {
        let args = Args::parse(
            &["x", "--density", "0.3", "--lambda-grid", "0.1,0.9"]
                .iter()
                .map(|s| s.to_string())
                .collect::<Vec<_>>(),
            &[],
        )
        .unwrap();
        let mut c = RunConfig::default();
        c.apply_args(&args).unwrap();
        assert_eq!(c.density, 0.3);
        assert_eq!(c.lambda_grid, vec![0.1, 0.9]);
    }

    #[test]
    fn watermark_knobs_parse_from_toml_and_cli() {
        let c = RunConfig::default();
        assert_eq!(c.high_water_bytes, 0, "default is derive-from-buffer");
        assert_eq!(c.low_water_bytes, 0);
        let mut c = RunConfig::default();
        c.apply_toml("high_water_bytes = 8192\nlow_water_bytes = 1024\n")
            .unwrap();
        assert_eq!(c.high_water_bytes, 8192);
        assert_eq!(c.low_water_bytes, 1024);
        let args = Args::parse(
            &["x", "--high-water-bytes", "4096", "--low-water-bytes", "512"]
                .iter()
                .map(|s| s.to_string())
                .collect::<Vec<_>>(),
            &[],
        )
        .unwrap();
        c.apply_args(&args).unwrap();
        assert_eq!(c.high_water_bytes, 4096, "CLI overrides the file");
        assert_eq!(c.low_water_bytes, 512);
    }

    #[test]
    fn backend_knob_parses_and_rejects_unknown_names() {
        let c = RunConfig::default();
        assert_eq!(c.backend, "auto", "default defers to the registry");
        let mut c = RunConfig::default();
        c.apply_toml("backend = \"cpu-q8\"\n").unwrap();
        assert_eq!(c.backend, "cpu-q8");
        let err = c.apply_toml("backend = \"cuda\"\n").unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("cuda"), "error names the bad value: {msg}");
        assert!(
            msg.contains("cpu-q8"),
            "error lists the registry: {msg}"
        );
        let args = Args::parse(
            &["x", "--backend", "sim"]
                .iter()
                .map(|s| s.to_string())
                .collect::<Vec<_>>(),
            &[],
        )
        .unwrap();
        c.apply_args(&args).unwrap();
        assert_eq!(c.backend, "sim", "CLI overrides the config file");
        let args = Args::parse(
            &["x", "--backend", "tpu"]
                .iter()
                .map(|s| s.to_string())
                .collect::<Vec<_>>(),
            &[],
        )
        .unwrap();
        assert!(
            c.apply_args(&args).is_err(),
            "unknown CLI backend is rejected at parse time"
        );
    }

    #[test]
    fn governor_knobs_default_off_and_override() {
        let c = RunConfig::default();
        assert!(!c.governor, "governor is opt-in");
        assert_eq!(c.governor_floors, [0.8, 0.5, 0.3]);
        assert_eq!(c.steal_threshold, 2.0);
        let mut c = RunConfig::default();
        c.apply_toml(
            "governor = true\ngovernor_floor_batch = 0.2\n\
             steal_threshold = 1.5\n",
        )
        .unwrap();
        assert!(c.governor);
        assert_eq!(c.governor_floors[2], 0.2);
        assert_eq!(c.steal_threshold, 1.5);
        let args = Args::parse(
            &[
                "x",
                "--governor",
                "off",
                "--governor-floor-interactive",
                "0.9",
            ]
            .iter()
            .map(|s| s.to_string())
            .collect::<Vec<_>>(),
            &[],
        )
        .unwrap();
        c.apply_args(&args).unwrap();
        assert!(!c.governor, "CLI overrides the config file");
        assert_eq!(c.governor_floors[0], 0.9);
        let args = Args::parse(
            &["x", "--governor", "maybe"]
                .iter()
                .map(|s| s.to_string())
                .collect::<Vec<_>>(),
            &[],
        )
        .unwrap();
        assert!(
            c.apply_args(&args).is_err(),
            "--governor takes only on|off"
        );
    }

    #[test]
    fn server_config_governor_builders_and_from_run() {
        let c = ServerConfig::new(4);
        assert!(!c.governor, "governor is opt-in");
        let c = c
            .with_governor(true)
            .with_governor_floors([0.9, 0.6, 0.2])
            .with_steal_threshold(1.25);
        assert!(c.governor);
        assert_eq!(c.governor_floors, [0.9, 0.6, 0.2]);
        assert_eq!(c.steal_threshold, 1.25);
        let run = RunConfig {
            governor: true,
            steal_threshold: 3.0,
            ..RunConfig::default()
        };
        let c = ServerConfig::from_run(&run, 4);
        assert!(c.governor, "governor rides along from_run");
        assert_eq!(c.steal_threshold, 3.0);
    }

    #[test]
    fn server_config_defaults_and_builder() {
        let c = ServerConfig::new(4);
        assert_eq!(c.batch_width, 4);
        assert_eq!(c.shards, 1, "default must be the unsharded server");
        assert_eq!(c.chunk_budget, 1);
        assert!(c.group_prefixes);
        assert_eq!(c.cache_dir, None);
        assert_eq!(c.backend, "auto");
        let c = c
            .with_bind("0.0.0.0:0")
            .with_shards(2)
            .with_cache_bytes(1 << 20)
            .with_chunk_budget(3)
            .with_max_frame_bytes(4096)
            .with_conn_buffer_bytes(1 << 17)
            .with_cache_dir(Some(PathBuf::from("/tmp/warm")))
            .with_group_prefixes(false)
            .with_backend("cpu-q8")
            .with_watermarks(8192, 2048);
        assert_eq!(c.bind, "0.0.0.0:0");
        assert_eq!(c.backend, "cpu-q8");
        assert_eq!(c.shards, 2);
        assert_eq!(c.cache_bytes, 1 << 20);
        assert_eq!(c.chunk_budget, 3);
        assert_eq!(c.max_frame_bytes, 4096);
        assert_eq!(c.conn_buffer_bytes, 1 << 17);
        assert_eq!(c.cache_dir, Some(PathBuf::from("/tmp/warm")));
        assert!(!c.group_prefixes);
        assert_eq!(c.high_water_bytes, 8192);
        assert_eq!(c.low_water_bytes, 2048);
    }

    #[test]
    fn watermarks_derive_when_unset() {
        let c = ServerConfig::new(1).with_conn_buffer_bytes(1 << 20);
        assert_eq!(c.resolved_high_water(), 1 << 20);
        assert_eq!(c.resolved_low_water(), 1 << 18, "low = high / 4");
        let c = c.with_watermarks(4096, 0);
        assert_eq!(c.resolved_high_water(), 4096);
        assert_eq!(c.resolved_low_water(), 1024);
        let c = c.with_watermarks(4096, 1 << 30);
        assert_eq!(
            c.resolved_low_water(),
            4096,
            "low is clamped to high so resume is always reachable"
        );
    }

    #[test]
    fn server_config_from_run_and_legacy_options() {
        let run = RunConfig {
            bind: "0.0.0.0:9".to_string(),
            shards: 2,
            cache_bytes: 12345,
            high_water_bytes: 777,
            backend: "cpu-q8".to_string(),
            ..RunConfig::default()
        };
        let c = ServerConfig::from_run(&run, 4);
        assert_eq!(c.bind, "0.0.0.0:9");
        assert_eq!(c.shards, 2);
        assert_eq!(c.batch_width, 4);
        assert_eq!(c.cache_bytes, 12345);
        assert_eq!(c.high_water_bytes, 777);
        assert_eq!(c.backend, "cpu-q8", "backend rides along from_run");

        let opts = crate::server::ServerOptions::new(4)
            .with_shards(2)
            .with_max_frame_bytes(4096)
            .with_cache_dir(Some(PathBuf::from("/tmp/w")));
        let c = ServerConfig::from(opts);
        assert_eq!(c.batch_width, 4);
        assert_eq!(c.shards, 2);
        assert_eq!(c.max_frame_bytes, 4096);
        assert_eq!(c.cache_dir, Some(PathBuf::from("/tmp/w")));
        assert_eq!(
            c.high_water_bytes, 0,
            "legacy options carry no watermark: derived defaults apply"
        );
    }
}
