//! Shared Long-Generation evaluation flow (Sec. 4 protocol, App. B.2):
//!
//! 1. dense greedy generation defines the reference trajectory and the
//!    per-step reference distributions (fused `generate` executable);
//! 2. each sparsification strategy builds its static mask from the
//!    prefill statistics (plus prior), exactly as at deployment;
//! 3. the sparse model is teacher-forced along the dense trajectory with
//!    one `score` call, yielding deviation PPL and top-100 KLD.
//!
//! A batch's prefill and dense trajectory are computed once and shared by
//! every strategy — the evaluation cost is one score pass per strategy.

use anyhow::{bail, Result};

use crate::engine::session::pack_slot_masks;
use crate::engine::{Engine, GenerateResult, PrefillResult};
use crate::eval::kld::topk_kld;
use crate::eval::ppl::{nll_per_token, ppl_from_nll};
use crate::glass::{build_mask, GlobalPrior, ImportanceMap, MaskSet, Strategy};
use crate::tensor::{TensorF, TensorI};
use crate::util::stats::{summarize, Summary};

/// One prepared evaluation batch: prompts, prefill evidence, and the
/// dense reference trajectory.
pub struct LgBatch {
    pub prompts: Vec<String>,
    pub b: usize,
    pub pre: PrefillResult,
    pub dense: GenerateResult,
    /// Teacher-forcing token frame [B, S_score] (BOS+prompt+trajectory).
    pub score_tokens: TensorI,
    /// Per-slot trajectory-start offset (prompt length incl. BOS).
    pub starts: Vec<usize>,
    /// Per-slot number of scored trajectory tokens.
    pub n_gen: usize,
}

/// Per-sample deviation metrics.
#[derive(Debug, Clone, Copy)]
pub struct SampleMetrics {
    pub ppl: f64,
    pub kld: f64,
}

/// Aggregated over samples (mean + spread, reported paper-style).
#[derive(Debug, Clone, Copy)]
pub struct StrategyMetrics {
    pub ppl: Summary,
    pub kld: Summary,
}

pub fn prepare_batch(engine: &Engine, prompts: &[String], b: usize) -> Result<LgBatch> {
    let spec = engine.spec().clone();
    let pre = engine.prefill(prompts, b)?;
    let dense = engine.generate(prompts, &engine.dense_mask(b), b)?;

    let n_gen = dense.tokens.shape[1];
    let s_score = spec.score_len;
    let (prompt_toks, lens, truncated) = engine.encode_prompts(prompts, b)?;
    if let Some(i) = truncated.iter().position(|&t| t) {
        // scoring a clipped prompt would silently misattribute quality;
        // fail loudly instead (the eval sets fit the prefill frame)
        bail!(
            "lgeval prompt {i} exceeds the prefill frame ({} tokens) and \
             would be tail-truncated",
            engine.spec().prefill_len
        );
    }
    let s_pre = spec.prefill_len;
    if lens.iter().any(|&l| l + n_gen > s_score) {
        bail!("prompt+trajectory exceeds score window");
    }
    let mut frame = vec![spec.pad_id; b * s_score];
    for slot in 0..b {
        let len = lens.get(slot).copied().unwrap_or(1);
        // prompt part
        for j in 0..len {
            frame[slot * s_score + j] = prompt_toks.data[slot * s_pre + j];
        }
        // trajectory part
        for i in 0..n_gen {
            frame[slot * s_score + len + i] =
                dense.tokens.data[slot * n_gen + i];
        }
    }
    Ok(LgBatch {
        prompts: prompts.to_vec(),
        b,
        starts: lens,
        pre,
        dense,
        score_tokens: TensorI::new(vec![b, s_score], frame)?,
        n_gen,
    })
}

/// Build per-slot masks for a strategy over this batch. For
/// [`Strategy::Oracle`] the post-hoc decode-time statistics (from the
/// dense trajectory) are used as the ranking signal, per App. C.1.
pub fn batch_masks(
    engine: &Engine,
    batch: &LgBatch,
    strategy: &Strategy,
    prior: Option<&GlobalPrior>,
    density: f64,
) -> Result<Vec<MaskSet>> {
    let spec = engine.spec();
    let k = spec.budget(density);
    let n = batch.prompts.len();
    let mut masks = Vec::with_capacity(n);
    for slot in 0..n {
        let signal = match strategy {
            Strategy::Oracle => {
                ImportanceMap::from_stats(&batch.dense.stats, slot)?
            }
            _ => ImportanceMap::from_stats(&batch.pre.stats, slot)?,
        };
        masks.push(build_mask(strategy, &signal, prior, k)?);
    }
    Ok(masks)
}

/// Teacher-force the masked model along the dense trajectory and compute
/// per-sample deviation PPL + top-`kld_top` KLD.
pub fn eval_masks(
    engine: &Engine,
    batch: &LgBatch,
    masks: &[MaskSet],
    kld_top: usize,
) -> Result<Vec<SampleMetrics>> {
    let spec = engine.spec().clone();
    let b = batch.b;
    let n = batch.prompts.len();
    let mask_t = pack_slot_masks(masks, n, b, &spec);
    let w = TensorF::zeros(&[b, spec.score_len]);
    let (logits, _) = engine.score(&batch.score_tokens, &w, &mask_t)?;

    let v = spec.vocab;
    let s_score = spec.score_len;
    let n_gen = batch.n_gen;
    let mut out = Vec::with_capacity(n);
    for slot in 0..n {
        let start = batch.starts[slot];
        // sparse logit rows for this slot as a [S, V] view
        let slot_logits = TensorF::new(
            vec![s_score, v],
            logits.data[slot * s_score * v..(slot + 1) * s_score * v]
                .to_vec(),
        )?;
        // PPL: target t_i predicted by row (start-1+i)
        let positions: Vec<usize> =
            (0..n_gen).map(|i| start - 1 + i).collect();
        let targets: Vec<i32> = (0..n_gen)
            .map(|i| batch.dense.tokens.data[slot * n_gen + i])
            .collect();
        let nll = nll_per_token(&slot_logits, &positions, &targets)?;
        let ppl = ppl_from_nll(&nll);

        // KLD: dense gen_logits[:, i] (dist after consuming t_i) vs
        // sparse row (start + i), for i = 0..n_gen-1
        let mut klds = Vec::with_capacity(n_gen);
        for i in 0..n_gen {
            let dense_row = &batch.dense.logits.data
                [(slot * n_gen + i) * v..(slot * n_gen + i + 1) * v];
            let sparse_row = slot_logits.row(start + i);
            klds.push(topk_kld(dense_row, sparse_row, kld_top)?);
        }
        out.push(SampleMetrics {
            ppl,
            kld: klds.iter().sum::<f64>() / klds.len() as f64,
        });
    }
    Ok(out)
}

/// Full pipeline over a prompt list: chunk into batches, prepare each
/// once, and evaluate every (name, strategy, prior) tuple.
pub fn eval_strategies(
    engine: &Engine,
    prompts: &[String],
    b: usize,
    strategies: &[(String, Strategy, Option<&GlobalPrior>)],
    density: f64,
    kld_top: usize,
) -> Result<Vec<(String, StrategyMetrics, Vec<SampleMetrics>)>> {
    let mut per_strategy: Vec<Vec<SampleMetrics>> =
        vec![Vec::new(); strategies.len()];
    for chunk in prompts.chunks(b) {
        let batch = prepare_batch(engine, chunk, b)?;
        for (si, (_, strat, prior)) in strategies.iter().enumerate() {
            let masks = batch_masks(engine, &batch, strat, *prior, density)?;
            let metrics = eval_masks(engine, &batch, &masks, kld_top)?;
            per_strategy[si].extend(metrics);
        }
    }
    Ok(strategies
        .iter()
        .zip(per_strategy)
        .map(|((name, _, _), samples)| {
            let ppls: Vec<f64> = samples.iter().map(|s| s.ppl).collect();
            let klds: Vec<f64> = samples.iter().map(|s| s.kld).collect();
            (
                name.clone(),
                StrategyMetrics {
                    ppl: summarize(&ppls),
                    kld: summarize(&klds),
                },
                samples,
            )
        })
        .collect())
}
