//! Fig. 4: λ sensitivity sweep — PPL at 50% density as the mixing weight
//! moves from 0 (GRIFFIN) to 1 (static global mask), I-GLASS (NPS).

use anyhow::Result;

use super::lgeval::eval_strategies;
use super::{lg_prompts, ExpReport};
use crate::config::RunConfig;
use crate::engine::Engine;
use crate::glass::{GlobalPrior, PriorKind, Strategy};
use crate::util::json::Json;
use crate::util::table::{fnum, Table};

pub fn run(engine: &Engine, cfg: &RunConfig) -> Result<ExpReport> {
    let prompts = lg_prompts(engine, cfg.sweep_samples)?;
    let i_nps = GlobalPrior::load(&engine.rt, PriorKind::INps)?;

    let strategies: Vec<(String, Strategy, Option<&GlobalPrior>)> = cfg
        .lambda_grid
        .iter()
        .map(|&lam| {
            (
                format!("λ={lam:.2}"),
                Strategy::Glass { lambda: lam },
                Some(&i_nps),
            )
        })
        .collect();

    let results = eval_strategies(
        engine,
        &prompts,
        cfg.batch,
        &strategies,
        cfg.density,
        cfg.kld_top,
    )?;

    let mut t = Table::new(
        &format!(
            "Fig. 4 — PPL vs λ @ {:.0}% density ({} samples, I-GLASS NPS)",
            cfg.density * 100.0,
            prompts.len()
        ),
        &["λ", "PPL", "KLD"],
    );
    let mut lambdas = Vec::new();
    let mut ppls = Vec::new();
    let mut klds = Vec::new();
    let mut best = (f64::INFINITY, 0.0);
    for (&lam, (_, m, _)) in cfg.lambda_grid.iter().zip(&results) {
        t.row(vec![
            format!("{lam:.2}"),
            fnum(m.ppl.mean, 4),
            fnum(m.kld.mean, 4),
        ]);
        lambdas.push(lam);
        ppls.push(m.ppl.mean);
        klds.push(m.kld.mean);
        if m.ppl.mean < best.0 {
            best = (m.ppl.mean, lam);
        }
    }
    crate::info!("fig4: best λ = {:.2} (PPL {:.4})", best.1, best.0);

    let mut json = Json::obj();
    json.set("density", Json::Num(cfg.density))
        .set("samples", Json::Num(prompts.len() as f64))
        .set("lambda", Json::from_f64_slice(&lambdas))
        .set("ppl", Json::from_f64_slice(&ppls))
        .set("kld", Json::from_f64_slice(&klds))
        .set("best_lambda", Json::Num(best.1));

    Ok(ExpReport {
        name: "fig4".into(),
        tables: vec![t],
        json,
    })
}
