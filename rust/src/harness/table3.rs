//! Table 3: KLD across activation densities (90%→10%) comparing the
//! NPS-derived global prior against the held-out-corpus prior ("Wiki"
//! in the paper), for both A-GLASS and I-GLASS, with GRIFFIN as the
//! local-only reference.

use anyhow::Result;

use super::lgeval::eval_strategies;
use super::{lg_prompts, ExpReport};
use crate::config::RunConfig;
use crate::engine::Engine;
use crate::glass::{GlobalPrior, PriorKind, Strategy};
use crate::util::json::Json;
use crate::util::table::{fnum, Table};

pub fn run(engine: &Engine, cfg: &RunConfig) -> Result<ExpReport> {
    let prompts = lg_prompts(engine, cfg.sweep_samples)?;
    let priors: Vec<(&str, GlobalPrior)> = vec![
        ("A-GLS (corpus)", GlobalPrior::load(&engine.rt, PriorKind::ACorpus)?),
        ("A-GLS (NPS)", GlobalPrior::load(&engine.rt, PriorKind::ANps)?),
        ("I-GLS (corpus)", GlobalPrior::load(&engine.rt, PriorKind::ICorpus)?),
        ("I-GLS (NPS)", GlobalPrior::load(&engine.rt, PriorKind::INps)?),
    ];

    let headers: Vec<&str> = std::iter::once("density %")
        .chain(std::iter::once("GRFN"))
        .chain(priors.iter().map(|(n, _)| *n))
        .collect();
    let mut t = Table::new(
        &format!(
            "Table 3 — KLD vs density, NPS vs corpus prior ({} samples)",
            prompts.len()
        ),
        &headers,
    );

    let mut json = Json::obj();
    json.set("samples", Json::Num(prompts.len() as f64));
    let mut rows_json = Vec::new();

    for &density in &cfg.density_grid {
        let mut strategies: Vec<(String, Strategy, Option<&GlobalPrior>)> =
            vec![("GRFN".into(), Strategy::LocalOnly, None)];
        for (name, p) in &priors {
            strategies.push((
                name.to_string(),
                Strategy::Glass { lambda: cfg.lambda },
                Some(p),
            ));
        }
        let results = eval_strategies(
            engine,
            &prompts,
            cfg.batch,
            &strategies,
            density,
            cfg.kld_top,
        )?;
        let mut row = vec![format!("{:.0}", density * 100.0)];
        let mut jrow = Json::obj();
        jrow.set("density", Json::Num(density));
        for (name, m, _) in &results {
            row.push(fnum(m.kld.mean, 4));
            jrow.set(name, Json::Num(m.kld.mean));
        }
        t.row(row);
        rows_json.push(jrow);
        crate::info!("table3: density {:.0}% done", density * 100.0);
    }
    json.set("rows", Json::Arr(rows_json));

    Ok(ExpReport {
        name: "table3".into(),
        tables: vec![t],
        json,
    })
}
