//! Oracle-overlap analysis (Table 5 + Fig. 1) and the end-to-end PPL
//! ablation (Table 6), per App. C.1.
//!
//! Oracle overlap: local masks come from prompt statistics, global masks
//! from the held-out-corpus prior (disjoint from the eval prompts), and
//! the oracle set is the top-k by post-hoc decoding-time activation on
//! the dense trajectory. Jaccard similarity to the oracle is reported per
//! layer (Fig. 1) and layer-aggregated (Tab. 5).

use anyhow::Result;

use super::lgeval::{batch_masks, eval_strategies, prepare_batch};
use super::{lg_prompts, ExpReport};
use crate::config::RunConfig;
use crate::engine::Engine;
use crate::glass::{GlobalPrior, PriorKind, Strategy};
use crate::util::json::Json;
use crate::util::stats::{mean, std_dev};
use crate::util::table::{fnum, mean_std, Table};

pub fn run_oracle_overlap(engine: &Engine, cfg: &RunConfig) -> Result<ExpReport> {
    let spec = engine.spec().clone();
    let prompts = lg_prompts(engine, cfg.oracle_samples)?;
    // the paper estimates A^g on a corpus disjoint from the oracle set
    let prior = GlobalPrior::load(&engine.rt, PriorKind::ACorpus)?;

    let variants: Vec<(&str, Strategy, Option<&GlobalPrior>)> = vec![
        ("Local-Only", Strategy::LocalOnly, None),
        ("Global-Only", Strategy::GlobalOnly, Some(&prior)),
        (
            "Global-Local (Ours)",
            Strategy::Glass { lambda: cfg.lambda },
            Some(&prior),
        ),
    ];

    // per variant, per layer, jaccards across samples
    let l = spec.n_layers;
    let mut jacc: Vec<Vec<Vec<f64>>> = vec![vec![Vec::new(); l]; 3];

    for chunk in prompts.chunks(cfg.batch) {
        let batch = prepare_batch(engine, chunk, cfg.batch)?;
        let oracle =
            batch_masks(engine, &batch, &Strategy::Oracle, None, cfg.density)?;
        for (vi, (_, strat, p)) in variants.iter().enumerate() {
            let masks = batch_masks(engine, &batch, strat, *p, cfg.density)?;
            for (slot, mask) in masks.iter().enumerate() {
                for li in 0..l {
                    jacc[vi][li]
                        .push(mask.jaccard_layer(&oracle[slot], li));
                }
            }
        }
    }

    // Table 5: layer-aggregated mean/std
    let mut t5 = Table::new(
        &format!(
            "Table 5 — Jaccard to oracle @ {:.0}% density ({} samples, {} layers)",
            cfg.density * 100.0,
            prompts.len(),
            l
        ),
        &["variant", "mean Jaccard", "std (across layers)"],
    );
    let mut json = Json::obj();
    json.set("density", Json::Num(cfg.density))
        .set("samples", Json::Num(prompts.len() as f64));

    let mut fig1 = Table::new(
        "Fig. 1 — per-layer Jaccard to oracle",
        &["layer", "Local-Only", "Global-Only", "Global-Local"],
    );
    let mut layer_means: Vec<Vec<f64>> = vec![Vec::new(); 3];
    for li in 0..l {
        let mut row = vec![li.to_string()];
        for vi in 0..3 {
            let m = mean(&jacc[vi][li]);
            layer_means[vi].push(m);
            row.push(fnum(m, 3));
        }
        fig1.row(row);
    }
    for (vi, (name, _, _)) in variants.iter().enumerate() {
        let m = mean(&layer_means[vi]);
        let s = std_dev(&layer_means[vi]);
        t5.row(vec![name.to_string(), fnum(m, 3), fnum(s, 3)]);
        let mut o = Json::obj();
        o.set("mean_jaccard", Json::Num(m))
            .set("std_across_layers", Json::Num(s))
            .set("per_layer", Json::from_f64_slice(&layer_means[vi]));
        json.set(name, o);
    }

    Ok(ExpReport {
        name: "table5_fig1".into(),
        tables: vec![t5, fig1],
        json,
    })
}

/// Table 6: end-to-end PPL ablation — Local-Only (λ=0, GRIFFIN),
/// Global-Only (λ=1, static global mask), Global+Local (λ=0.5, I-GLASS).
pub fn run_ablation(engine: &Engine, cfg: &RunConfig) -> Result<ExpReport> {
    let prompts = lg_prompts(engine, cfg.lg_samples)?;
    let i_nps = GlobalPrior::load(&engine.rt, PriorKind::INps)?;

    let strategies = vec![
        (
            "Local-Only (λ=0; GRIFFIN)".to_string(),
            Strategy::Glass { lambda: 0.0 },
            Some(&i_nps),
        ),
        (
            "Global-Only (λ=1; static global)".to_string(),
            Strategy::Glass { lambda: 1.0 },
            Some(&i_nps),
        ),
        (
            "Global+Local (λ=0.5; I-GLASS)".to_string(),
            Strategy::Glass { lambda: 0.5 },
            Some(&i_nps),
        ),
    ];
    let results = eval_strategies(
        engine,
        &prompts,
        cfg.batch,
        &strategies,
        cfg.density,
        cfg.kld_top,
    )?;

    let mut t = Table::new(
        &format!(
            "Table 6 — PPL ablation @ {:.0}% density ({} samples); \
             std across samples in parens",
            cfg.density * 100.0,
            prompts.len()
        ),
        &["variant", "PPL (std)"],
    );
    let mut json = Json::obj();
    json.set("density", Json::Num(cfg.density))
        .set("samples", Json::Num(prompts.len() as f64));
    for (name, m, _) in &results {
        t.row(vec![name.clone(), mean_std(m.ppl.mean, m.ppl.std, 4)]);
        let mut o = Json::obj();
        o.set("ppl_mean", Json::Num(m.ppl.mean))
            .set("ppl_std", Json::Num(m.ppl.std))
            .set("kld_mean", Json::Num(m.kld.mean));
        json.set(name, o);
    }

    Ok(ExpReport {
        name: "table6".into(),
        tables: vec![t],
        json,
    })
}
