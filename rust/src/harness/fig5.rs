//! Fig. 5 / §4.5: on-device decode speedup from 50% static FFN masking.
//!
//! Two parts (DESIGN.md §3 substitution):
//!  * the edge-memory simulator replays the paper's three workloads on a
//!    Galaxy-S25-class profile — Qwen3-4B (int4, fits RAM), Llama3-8B
//!    (int4, fits), Gemma-7B (bf16, does NOT fit dense → residency
//!    transition), reproducing the 20% / 42% / ~11× shape;
//!  * real measured decode latency of our model via the bench targets
//!    (bench_decode) complements this with actual wall-clock numbers.

use anyhow::Result;

use super::ExpReport;
use crate::config::RunConfig;
use crate::engine::Engine;
use crate::memsim::{decode_speedup, DeviceProfile, SimModel};
use crate::util::json::Json;
use crate::util::table::{fnum, Table};

/// The paper's §4.5 workloads. ffn_fraction estimated from the public
/// architectures (gate+up+down vs total), bytes/param from the deployment
/// quantization that makes the paper's dense baselines runnable at all
/// on a 12 GB phone (int4 for Qwen/Llama; Gemma-7B bf16 exceeds RAM,
/// which is exactly the case the paper highlights).
pub fn paper_workloads() -> Vec<(SimModel, usize, f64)> {
    vec![
        // (model, decode_tokens, paper_speedup)
        (
            SimModel::paper_workload("Qwen3 4B (int4)", 4.0, 0.5, 0.70),
            256,
            1.20,
        ),
        (
            SimModel::paper_workload("Llama3 8B (int4)", 8.0, 0.5, 0.67),
            256,
            1.42,
        ),
        (
            SimModel::paper_workload("Gemma 7B (bf16)", 8.5, 2.0, 0.81),
            128,
            11.0,
        ),
    ]
}

pub fn run(engine: &Engine, cfg: &RunConfig) -> Result<ExpReport> {
    let dev = DeviceProfile::galaxy_s25_ultra();
    let mut t = Table::new(
        &format!(
            "Fig. 5 — simulated decode speedup @ {:.0}% FFN density on {}",
            cfg.density * 100.0,
            dev.name
        ),
        &[
            "workload",
            "dense tok/s",
            "GLASS tok/s",
            "speedup",
            "paper",
            "dense resident",
            "sparse resident",
        ],
    );
    let mut json = Json::obj();
    let mut rows = Vec::new();
    for (model, tokens, paper) in paper_workloads() {
        let (dense, sparse, speedup) =
            decode_speedup(&dev, &model, cfg.density, tokens);
        t.row(vec![
            model.name.clone(),
            fnum(dense.tokens_per_s, 1),
            fnum(sparse.tokens_per_s, 1),
            format!("{speedup:.2}x"),
            format!("{paper:.2}x"),
            dense.resident.to_string(),
            sparse.resident.to_string(),
        ]);
        let mut o = Json::obj();
        o.set("dense_tok_s", Json::Num(dense.tokens_per_s))
            .set("sparse_tok_s", Json::Num(sparse.tokens_per_s))
            .set("speedup", Json::Num(speedup))
            .set("paper_speedup", Json::Num(paper))
            .set("dense_resident", Json::Bool(dense.resident))
            .set("sparse_resident", Json::Bool(sparse.resident));
        json.set(&model.name, o);
        rows.push(speedup);
    }

    // our real model measured through the runtime: one masked decode step
    // dense vs 50% top-k gathered step (FLOP-reducing path)
    let real = measure_real_decode(engine, cfg)?;
    let mut t2 = Table::new(
        "Fig. 5b — measured decode step latency (our model, this host)",
        &["variant", "ms/step", "speedup vs dense"],
    );
    for (name, ms) in &real {
        t2.row(vec![
            name.clone(),
            fnum(*ms, 3),
            format!("{:.2}x", real[0].1 / ms),
        ]);
        json.set(
            &format!("measured_{}", name.replace(' ', "_")),
            Json::Num(*ms),
        );
    }

    Ok(ExpReport {
        name: "fig5".into(),
        tables: vec![t, t2],
        json,
    })
}

/// Measure per-step decode latency: dense mask vs 50% masked vs top-k
/// gathered, batch 1.
pub fn measure_real_decode(
    engine: &Engine,
    _cfg: &RunConfig,
) -> Result<Vec<(String, f64)>> {
    use crate::glass::{build_mask, pack_indices, Strategy};
    use crate::tensor::TensorF;

    let spec = engine.spec().clone();
    let prompts = vec!["once there was a red fox".to_string()];
    let pre = engine.prefill(&prompts, 1)?;
    let local = engine.local_importance(&pre, 0)?;
    let k = engine.rt.manifest.topk_k;
    let mask_half = build_mask(&Strategy::LocalOnly, &local, None, k)?;
    let idx = pack_indices(&[&mask_half], spec.n_layers, k)?;

    let dense_mask = engine.dense_mask(1);
    let mut half_mask_t =
        TensorF::zeros(&[1, spec.n_layers, spec.ffn_m]);
    for li in 0..spec.n_layers {
        let lm = mask_half.layer_mask(li);
        half_mask_t.data[li * spec.ffn_m..(li + 1) * spec.ffn_m]
            .copy_from_slice(&lm);
    }

    let reps = 30;
    let mut out = Vec::new();
    // warm + measure each variant
    for (name, topk) in [
        ("dense (mask=1)", false),
        ("masked 50%", false),
        ("topk 50% (pallas)", true),
    ] {
        let mask = if name.starts_with("dense") {
            &dense_mask
        } else {
            &half_mask_t
        };
        let mut kv = pre.kv.clone();
        let tok = [65i32];
        let pos = [pre.lens[0] as i32];
        // warmup (compile)
        if topk {
            engine.decode_step_topk(&mut kv, &tok, &pos, &idx)?;
        } else {
            engine.decode_step(&mut kv, &tok, &pos, mask)?;
        }
        let t0 = std::time::Instant::now();
        for _ in 0..reps {
            if topk {
                engine.decode_step_topk(&mut kv, &tok, &pos, &idx)?;
            } else {
                engine.decode_step(&mut kv, &tok, &pos, mask)?;
            }
        }
        let ms = t0.elapsed().as_secs_f64() * 1e3 / reps as f64;
        out.push((name.to_string(), ms));
    }
    Ok(out)
}
