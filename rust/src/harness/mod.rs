//! Experiment harness: one runner per paper table/figure (DESIGN.md §6).
//!
//! Every runner regenerates its table/figure from scratch through the
//! public API (engine + glass + eval), prints the rows in the paper's
//! layout, and writes machine-readable JSON plus a markdown table under
//! `results/`. The EXPERIMENTS.md paper-vs-measured entries are built
//! from those outputs.

pub mod ablation;
pub mod fig4;
pub mod fig5;
pub mod lgeval;
pub mod table1;
pub mod table2;
pub mod table3;
pub mod table56;

use std::path::Path;

use anyhow::Result;

use crate::config::RunConfig;
use crate::engine::Engine;
use crate::util::json::Json;
use crate::util::table::Table;

/// What a runner produces.
pub struct ExpReport {
    pub name: String,
    pub tables: Vec<Table>,
    pub json: Json,
}

impl ExpReport {
    /// Print to stdout + persist under cfg.results_dir.
    pub fn emit(&self, cfg: &RunConfig) -> Result<()> {
        for t in &self.tables {
            println!("{}", t.to_ascii());
        }
        std::fs::create_dir_all(&cfg.results_dir)?;
        let jpath = cfg.results_dir.join(format!("{}.json", self.name));
        self.json.write_file(&jpath)?;
        let mut md = String::new();
        for t in &self.tables {
            md.push_str(&t.to_markdown());
            md.push('\n');
        }
        std::fs::write(
            cfg.results_dir.join(format!("{}.md", self.name)),
            md,
        )?;
        crate::info!("wrote results/{}.{{json,md}}", self.name);
        Ok(())
    }
}

/// All experiment ids, in suggested run order.
pub const EXPERIMENTS: &[&str] = &[
    "table1", "table2", "table3", "table5", "table6", "fig1", "fig4",
    "fig5", "ablation",
];

/// Dispatch one experiment by id.
pub fn run_experiment(
    id: &str,
    engine: &Engine,
    cfg: &RunConfig,
) -> Result<ExpReport> {
    match id {
        "table1" => table1::run(engine, cfg),
        "table2" => table2::run(engine, cfg),
        "table3" => table3::run(engine, cfg),
        // table 5 and fig 1 come from the same oracle-overlap analysis
        "table5" | "fig1" => table56::run_oracle_overlap(engine, cfg),
        "table6" => table56::run_ablation(engine, cfg),
        "fig4" => fig4::run(engine, cfg),
        "ablation" => ablation::run(engine, cfg),
        "fig5" => fig5::run(engine, cfg),
        other => anyhow::bail!(
            "unknown experiment '{other}' (have: {})",
            EXPERIMENTS.join(", ")
        ),
    }
}

/// Load the LG prompt list, truncated to n samples. Without a bundled
/// dataset (simulator runtime) a deterministic grammar-world prompt
/// list is generated instead, so the harness and profiler still run.
pub fn lg_prompts(engine: &Engine, n: usize) -> Result<Vec<String>> {
    if let Ok(path) = engine.rt.manifest.data_path("lg") {
        if path.exists() {
            let set = crate::data::LgSet::load(Path::new(&path))?;
            let mut prompts = set.prompts;
            prompts.truncate(n);
            return Ok(prompts);
        }
    }
    let adjectives = ["red", "blue", "golden", "grey", "quiet", "quick"];
    let animals = ["fox", "owl", "wolf", "otter", "cat", "raven"];
    Ok((0..n)
        .map(|i| {
            format!(
                "once there was a {} {}",
                adjectives[i % adjectives.len()],
                animals[(i / adjectives.len() + i) % animals.len()]
            )
        })
        .collect())
}
