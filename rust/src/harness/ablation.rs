//! Selector ablation (beyond the paper's tables): every mask-selection
//! strategy implemented in `glass::selector` evaluated under the LG
//! deviation protocol at one density — GLASS and GRIFFIN alongside the
//! related-work baselines (CATS-like offline thresholding, TDA-like
//! prefill thresholding), the post-hoc oracle upper reference, and the
//! random floor. `glass exp ablation`.

use anyhow::Result;

use super::lgeval::eval_strategies;
use super::{lg_prompts, ExpReport};
use crate::config::RunConfig;
use crate::engine::Engine;
use crate::glass::{GlobalPrior, PriorKind, Strategy};
use crate::util::json::Json;
use crate::util::table::{improvement_pct, mean_std, Table};

pub fn run(engine: &Engine, cfg: &RunConfig) -> Result<ExpReport> {
    let prompts = lg_prompts(engine, cfg.lg_samples)?;
    let a_nps = GlobalPrior::load(&engine.rt, PriorKind::ANps)?;
    let i_nps = GlobalPrior::load(&engine.rt, PriorKind::INps)?;

    let strategies: Vec<(String, Strategy, Option<&GlobalPrior>)> = vec![
        ("Random (floor)".into(), Strategy::Random { seed: cfg.seed }, None),
        ("TDA-like (prefill threshold)".into(), Strategy::TdaThreshold, None),
        ("CATS-like (offline threshold)".into(), Strategy::CatsThreshold,
         Some(&a_nps)),
        ("GRIFFIN (local-only)".into(), Strategy::LocalOnly, None),
        ("Global-only".into(), Strategy::GlobalOnly, Some(&a_nps)),
        (
            "A-GLASS".into(),
            Strategy::Glass { lambda: cfg.lambda },
            Some(&a_nps),
        ),
        (
            "I-GLASS".into(),
            Strategy::Glass { lambda: cfg.lambda },
            Some(&i_nps),
        ),
        ("Oracle (post-hoc upper ref)".into(), Strategy::Oracle, None),
    ];
    let results = eval_strategies(
        engine,
        &prompts,
        cfg.batch,
        &strategies,
        cfg.density,
        cfg.kld_top,
    )?;

    let rand_kld = results[0].1.kld.mean;
    let mut t = Table::new(
        &format!(
            "Selector ablation — LG deviation @ {:.0}% density ({} samples)",
            cfg.density * 100.0,
            prompts.len()
        ),
        &["selector", "PPL (sem)", "KLD (sem)", "KLD vs random"],
    );
    let mut json = Json::obj();
    json.set("density", Json::Num(cfg.density))
        .set("samples", Json::Num(prompts.len() as f64));
    for (name, m, _) in &results {
        t.row(vec![
            name.clone(),
            mean_std(m.ppl.mean, m.ppl.sem(), 4),
            mean_std(m.kld.mean, m.kld.sem(), 4),
            format!("{:+.1}%", improvement_pct(rand_kld, m.kld.mean)),
        ]);
        let mut o = Json::obj();
        o.set("ppl_mean", Json::Num(m.ppl.mean))
            .set("kld_mean", Json::Num(m.kld.mean));
        json.set(name, o);
    }

    Ok(ExpReport {
        name: "ablation".into(),
        tables: vec![t],
        json,
    })
}
