//! Table 1: classification (0-shot unnormalized accuracy) and short-form
//! generation at 50% FFN sparsity — I-GLASS vs GRIFFIN.
//!
//! Classification protocol: for each item, build [BOS + context + option]
//! frames; the mask comes from *context* statistics (one dense score pass
//! with context-weighted stats aggregation gives A^l); the masked model
//! scores each option by summed token log-prob; prediction = argmax.
//!
//! Short-generation protocol: sparse generation (prefill → mask → fused
//! generate), scored with ROUGE-1/2/L (summarization families) or
//! token-F1 / exact match (QA families).

use anyhow::Result;

use super::ExpReport;
use crate::config::RunConfig;
use crate::data::{ClsSet, SgSet};
use crate::engine::session::pack_slot_masks;
use crate::engine::Engine;
use crate::eval::ppl::option_logprob;
use crate::eval::rouge::rouge_all;
use crate::eval::text_metrics::{exact_match, token_f1};
use crate::glass::{build_mask, GlobalPrior, ImportanceMap, PriorKind, Strategy};
use crate::tensor::{TensorF, TensorI};
use crate::util::json::Json;
use crate::util::stats::mean;
use crate::util::table::{fnum, Table};

pub fn run(engine: &Engine, cfg: &RunConfig) -> Result<ExpReport> {
    let i_nps = GlobalPrior::load(&engine.rt, PriorKind::INps)?;
    let methods: Vec<(&str, Strategy, Option<&GlobalPrior>)> = vec![
        (
            "I-GLASS",
            Strategy::Glass { lambda: cfg.lambda },
            Some(&i_nps),
        ),
        ("GRIFFIN", Strategy::LocalOnly, None),
    ];

    // ---------------------------------------------------- classification
    let cls = ClsSet::load(&engine.rt.manifest.data_path("cls")?)?;
    let mut cls_table = Table::new(
        &format!(
            "Table 1a — classification accuracy @ {:.0}% density \
             ({} items/family)",
            cfg.density * 100.0,
            cfg.cls_samples
        ),
        &["method", "family", "accuracy"],
    );
    let mut json = Json::obj();
    let mut cls_json = Json::obj();
    for (mname, strat, prior) in &methods {
        let mut fam_json = Json::obj();
        for family in cls.families() {
            let items: Vec<_> = cls
                .by_family(&family)
                .into_iter()
                .take(cfg.cls_samples)
                .collect();
            let mut correct = 0usize;
            for item in &items {
                let pred = classify_item(
                    engine, cfg, item, strat, *prior,
                )?;
                if pred == item.answer {
                    correct += 1;
                }
            }
            let acc = correct as f64 / items.len().max(1) as f64;
            cls_table.row(vec![
                mname.to_string(),
                family.clone(),
                fnum(acc * 100.0, 2),
            ]);
            fam_json.set(&family, Json::Num(acc));
        }
        cls_json.set(mname, fam_json);
        crate::info!("table1: classification done for {mname}");
    }
    json.set("classification", cls_json);

    // ------------------------------------------------- short generation
    let sg = SgSet::load(&engine.rt.manifest.data_path("sg")?)?;
    let mut sg_table = Table::new(
        &format!(
            "Table 1b — short-form generation @ {:.0}% density \
             ({} items/family)",
            cfg.density * 100.0,
            cfg.sg_samples
        ),
        &["method", "family", "metric", "score"],
    );
    let mut sg_json = Json::obj();
    for (mname, strat, prior) in &methods {
        let mut fam_json = Json::obj();
        for family in sg.families() {
            let items: Vec<_> = sg
                .by_family(&family)
                .into_iter()
                .take(cfg.sg_samples)
                .collect();
            let scores = eval_sg_family(engine, cfg, &items, strat, *prior)?;
            for (metric, vals) in &scores {
                sg_table.row(vec![
                    mname.to_string(),
                    family.clone(),
                    metric.clone(),
                    fnum(mean(vals) * 100.0, 2),
                ]);
                fam_json.set(
                    &format!("{family}.{metric}"),
                    Json::Num(mean(vals)),
                );
            }
        }
        sg_json.set(mname, fam_json);
        crate::info!("table1: short-generation done for {mname}");
    }
    json.set("short_generation", sg_json);

    Ok(ExpReport {
        name: "table1".into(),
        tables: vec![cls_table, sg_table],
        json,
    })
}

/// Score one MCQ item; returns the predicted option index.
fn classify_item(
    engine: &Engine,
    cfg: &RunConfig,
    item: &crate::data::ClsItem,
    strategy: &Strategy,
    prior: Option<&GlobalPrior>,
) -> Result<usize> {
    let spec = engine.spec().clone();
    let s = spec.score_len;
    let b = engine.pick_batch(item.options.len().min(4))?;
    let ctx_ids = {
        let mut v = vec![spec.bos_id];
        v.extend(engine.tok.encode(&item.context));
        v.truncate(s);
        v
    };
    let ctx_len = ctx_ids.len();

    // frames: context + option per slot (options beyond b handled in
    // chunks — families here have <= 4 options)
    let n_opt = item.options.len();
    if n_opt > b {
        anyhow::bail!("more options than batch width");
    }
    let mut frame = vec![spec.pad_id; b * s];
    let mut opt_tokens: Vec<Vec<i32>> = Vec::with_capacity(n_opt);
    for (oi, opt) in item.options.iter().enumerate() {
        let ids = engine.tok.encode(opt);
        let take = ids.len().min(s - ctx_len);
        frame[oi * s..oi * s + ctx_len].copy_from_slice(&ctx_ids);
        frame[oi * s + ctx_len..oi * s + ctx_len + take]
            .copy_from_slice(&ids[..take]);
        opt_tokens.push(ids[..take].to_vec());
    }
    let tokens = TensorI::new(vec![b, s], frame)?;

    // pass 1 (dense): context-weighted stats -> local importance A^l
    let mut w = TensorF::zeros(&[b, s]);
    for oi in 0..n_opt {
        for j in 0..ctx_len {
            w.data[oi * s + j] = 1.0 / ctx_len as f32;
        }
    }
    let (_, stats) = engine.score(&tokens, &w, &engine.dense_mask(b))?;
    // context stats are identical across option slots; use slot 0
    let local = ImportanceMap::from_stats(&stats, 0)?;
    let mask = build_mask(strategy, &local, prior, spec.budget(cfg.density))?;

    // pass 2 (masked): option log-probs
    let masks: Vec<_> = (0..n_opt).map(|_| mask.clone()).collect();
    let mask_t = pack_slot_masks(&masks, n_opt, b, &spec);
    let w0 = TensorF::zeros(&[b, s]);
    let (logits, _) = engine.score(&tokens, &w0, &mask_t)?;

    let v = spec.vocab;
    let mut best = (f64::NEG_INFINITY, 0usize);
    for (oi, opt_ids) in opt_tokens.iter().enumerate() {
        let slot_logits = TensorF::new(
            vec![s, v],
            logits.data[oi * s * v..(oi + 1) * s * v].to_vec(),
        )?;
        // option token i sits at position ctx_len+i, predicted by the
        // row at ctx_len+i-1
        let lp = option_logprob(&slot_logits, ctx_len - 1, opt_ids)?;
        if lp > best.0 {
            best = (lp, oi);
        }
    }
    Ok(best.1)
}

/// Sparse generation + text metrics for one SG family.
fn eval_sg_family(
    engine: &Engine,
    cfg: &RunConfig,
    items: &[&crate::data::SgItem],
    strategy: &Strategy,
    prior: Option<&GlobalPrior>,
) -> Result<Vec<(String, Vec<f64>)>> {
    let spec = engine.spec().clone();
    let b = cfg.batch;
    let k = spec.budget(cfg.density);
    let mut r1 = Vec::new();
    let mut r2 = Vec::new();
    let mut rl = Vec::new();
    let mut f1s = Vec::new();
    let mut ems = Vec::new();

    for chunk in items.chunks(b) {
        let prompts: Vec<String> =
            chunk.iter().map(|i| i.prompt.clone()).collect();
        let pre = engine.prefill(&prompts, b)?;
        let mut masks = Vec::with_capacity(prompts.len());
        for slot in 0..prompts.len() {
            let local = engine.local_importance(&pre, slot)?;
            masks.push(build_mask(strategy, &local, prior, k)?);
        }
        let mask_t = pack_slot_masks(&masks, prompts.len(), b, &spec);
        let gen = engine.generate(&prompts, &mask_t, b)?;
        let n = gen.tokens.shape[1];
        for (slot, item) in chunk.iter().enumerate() {
            let text =
                engine.decode_text(&gen.tokens.data[slot * n..(slot + 1) * n]);
            let answer = first_sentence(&text);
            if item.metric == "rouge" {
                let r = rouge_all(&answer, &item.reference);
                r1.push(r.rouge1);
                r2.push(r.rouge2);
                rl.push(r.rouge_l);
            } else {
                f1s.push(token_f1(&answer, &item.reference));
                ems.push(if exact_match(&answer, &item.reference) {
                    1.0
                } else {
                    0.0
                });
            }
        }
    }
    let mut out = Vec::new();
    if !r1.is_empty() {
        out.push(("rouge1".to_string(), r1));
        out.push(("rouge2".to_string(), r2));
        out.push(("rougeL".to_string(), rl));
    }
    if !f1s.is_empty() {
        out.push(("f1".to_string(), f1s));
        out.push(("em".to_string(), ems));
    }
    Ok(out)
}

/// Generated answers end at the first period (the grammar's sentence
/// boundary); everything after is continuation babble.
fn first_sentence(text: &str) -> String {
    match text.find('.') {
        Some(i) => text[..i].trim().to_string(),
        None => text.trim().to_string(),
    }
}

#[cfg(test)]
mod tests {
    use super::first_sentence;

    #[test]
    fn cuts_at_period() {
        assert_eq!(first_sentence(" red. the fox"), "red");
        assert_eq!(first_sentence("no period"), "no period");
    }
}
