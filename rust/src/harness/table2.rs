//! Table 2: Long-Generation deviation PPL and top-100 KLD at 50% FFN
//! sparsity — GRIFFIN vs A-GLASS (NPS) vs I-GLASS (NPS), with the paper's
//! "Imp%" improvement-over-GRIFFIN columns.

use anyhow::Result;

use super::lgeval::eval_strategies;
use super::{lg_prompts, ExpReport};
use crate::config::RunConfig;
use crate::engine::Engine;
use crate::glass::{GlobalPrior, PriorKind, Strategy};
use crate::util::json::Json;
use crate::util::table::{improvement_pct, mean_std, Table};

pub fn run(engine: &Engine, cfg: &RunConfig) -> Result<ExpReport> {
    let prompts = lg_prompts(engine, cfg.lg_samples)?;
    let a_nps = GlobalPrior::load(&engine.rt, PriorKind::ANps)?;
    let i_nps = GlobalPrior::load(&engine.rt, PriorKind::INps)?;

    let strategies = vec![
        ("GRIFFIN".to_string(), Strategy::LocalOnly, None),
        (
            "A-GLASS".to_string(),
            Strategy::Glass { lambda: cfg.lambda },
            Some(&a_nps),
        ),
        (
            "I-GLASS".to_string(),
            Strategy::Glass { lambda: cfg.lambda },
            Some(&i_nps),
        ),
    ];
    let results = eval_strategies(
        engine,
        &prompts,
        cfg.batch,
        &strategies,
        cfg.density,
        cfg.kld_top,
    )?;

    let grif_ppl = results[0].1.ppl.mean;
    let grif_kld = results[0].1.kld.mean;

    let mut t = Table::new(
        &format!(
            "Table 2 — LG PPL/KLD @ {:.0}% density ({} samples)",
            cfg.density * 100.0,
            prompts.len()
        ),
        &["metric", "GRIFFIN", "A-GLASS", "Imp%", "I-GLASS", "Imp%"],
    );
    t.row(vec![
        "PPL".into(),
        mean_std(results[0].1.ppl.mean, results[0].1.ppl.sem(), 4),
        mean_std(results[1].1.ppl.mean, results[1].1.ppl.sem(), 4),
        format!("{:.2}%", improvement_pct(grif_ppl, results[1].1.ppl.mean)),
        mean_std(results[2].1.ppl.mean, results[2].1.ppl.sem(), 4),
        format!("{:.2}%", improvement_pct(grif_ppl, results[2].1.ppl.mean)),
    ]);
    t.row(vec![
        "KLD".into(),
        mean_std(results[0].1.kld.mean, results[0].1.kld.sem(), 4),
        mean_std(results[1].1.kld.mean, results[1].1.kld.sem(), 4),
        format!("{:.2}%", improvement_pct(grif_kld, results[1].1.kld.mean)),
        mean_std(results[2].1.kld.mean, results[2].1.kld.sem(), 4),
        format!("{:.2}%", improvement_pct(grif_kld, results[2].1.kld.mean)),
    ]);

    let mut json = Json::obj();
    json.set("density", Json::Num(cfg.density))
        .set("samples", Json::Num(prompts.len() as f64));
    for (name, m, _) in &results {
        let mut o = Json::obj();
        o.set("ppl_mean", Json::Num(m.ppl.mean))
            .set("ppl_sem", Json::Num(m.ppl.sem()))
            .set("ppl_std", Json::Num(m.ppl.std))
            .set("kld_mean", Json::Num(m.kld.mean))
            .set("kld_sem", Json::Num(m.kld.sem()))
            .set("ppl_imp_pct", Json::Num(improvement_pct(grif_ppl, m.ppl.mean)))
            .set("kld_imp_pct", Json::Num(improvement_pct(grif_kld, m.kld.mean)));
        json.set(name, o);
    }

    Ok(ExpReport {
        name: "table2".into(),
        tables: vec![t],
        json,
    })
}
